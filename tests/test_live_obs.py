"""Live telemetry layer: time-series sampler, burn-rate alerts, exporter.

The unit tests pin the math the dashboards depend on: bucket-interpolated
histogram quantiles against exact percentiles, counter-rate first
differences, the multi-window burn-rate crossing (both windows must
exceed the threshold, with a minimum event floor and hysteresis on
clear), and the Prometheus text exposition shape.  Integration tests run
real serves — simulated and cluster — and assert the sampler ticks off
the serving clock, the exporter answers live scrapes mid-serve, and the
flight recorder embeds the pre-crash time-series window.
"""
import json
import urllib.request

import numpy as np
import pytest

from repro.launch.serve import build_parser, run_serve
from repro.obs import (NULL_BURN, NULL_SAMPLER, BurnRateTracker,
                       FlightRecorder, MetricsExporter, MetricsRegistry,
                       TimeSeriesSampler, Tracer, prometheus_text)
from repro.serving import (MasterScheduler, ServeConfig, SimulatedBackend,
                           TenantSpec, build_workload, run_load)


# ------------------------------------------------------------- quantiles

def test_histogram_quantile_tracks_exact_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=tuple(np.linspace(0.01, 2.0, 200)))
    rng = np.random.default_rng(17)
    vals = rng.uniform(0.02, 1.8, size=2000)
    for v in vals:
        h.observe(v)
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(vals, q))
        est = h.quantile(q)
        # dense buckets: interpolation lands within one bucket width
        assert est == pytest.approx(exact, abs=0.02), q


def test_histogram_quantile_edges_and_snapshot_keys():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    assert h.quantile(0.5) is None             # empty histogram
    for v in (0.01, 0.02, 0.03, 0.5):
        h.observe(v)
    # p0/p1 clamp to the observed extremes, not bucket bounds
    assert h.quantile(0.0) == pytest.approx(0.01)
    assert h.quantile(1.0) <= 1.0
    v = h.to_value()
    assert "p50" in v and "p99" in v
    assert v["p50"] <= v["p99"]
    with pytest.raises(ValueError, match="quantile"):
        h.quantile(1.5)


def test_histogram_quantile_overflow_bucket_pins_to_observed_max():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0,))
    for v in (5.0, 7.0, 9.0):
        h.observe(v)                           # all overflow
    q = h.quantile(0.99)
    assert 1.0 <= q <= 9.0


# --------------------------------------------------------------- sampler

def test_sampler_interval_gating_and_ring():
    reg = MetricsRegistry()
    c = reg.counter("x")
    s = TimeSeriesSampler(reg, interval=0.25, capacity=4)
    assert s.tick(0.0)                         # first tick always samples
    assert not s.tick(0.125)                   # inside the interval
    c.inc(2)
    assert s.tick(0.25)
    for t in (0.5, 0.75, 1.0, 1.25):
        c.inc()
        s.tick(t)
    assert len(s) == 4                         # ring evicted the oldest
    assert s.n_samples == 6                    # lifetime count keeps going
    assert s.samples()[0]["t"] == pytest.approx(0.5)
    assert [r["t"] for r in s.last(2)] == [pytest.approx(1.0),
                                           pytest.approx(1.25)]


def test_sampler_series_rates_are_per_second_first_differences():
    reg = MetricsRegistry()
    c = reg.counter("serve.slo_hit.a")
    s = TimeSeriesSampler(reg, interval=0.5)
    s.tick(0.0)
    c.inc(10)
    s.tick(0.5)
    c.inc(5)
    s.tick(1.0)
    ser = s.series()
    assert ser["kind"] == "timeseries"
    assert ser["counters"]["serve.slo_hit.a"] == [0.0, 10.0, 15.0]
    assert ser["rates"]["serve.slo_hit.a"] == \
        [0.0, pytest.approx(20.0), pytest.approx(10.0)]


def test_sampler_backfills_instruments_born_mid_run():
    reg = MetricsRegistry()
    s = TimeSeriesSampler(reg, interval=0.1)
    s.tick(0.0)
    reg.counter("late").inc(4)
    s.tick(0.2)
    ser = s.series()
    assert ser["counters"]["late"] == [0.0, 4.0]
    assert ser["rates"]["late"][1] == pytest.approx(20.0)


def test_sampler_validation_and_null():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="interval"):
        TimeSeriesSampler(reg, interval=0.0)
    with pytest.raises(ValueError, match="capacity"):
        TimeSeriesSampler(reg, capacity=0)
    assert not NULL_SAMPLER.enabled
    assert NULL_SAMPLER.tick(1.0) is False
    assert NULL_SAMPLER.series()["samples"] == 0


# ------------------------------------------------------------- burn rate

def _feed(bt, tenant, outcomes, t0=0.0, dt=0.1):
    alerts = []
    t = t0
    for hit in outcomes:
        a = bt.observe(tenant, hit, t)
        if a is not None:
            alerts.append(a)
        t += dt
    return alerts, t


def test_burn_alert_requires_both_windows_and_min_events():
    bt = BurnRateTracker(objective=0.9, window=6.0, min_events=10)
    # 9 misses: under the event floor, must not fire however bad the burn
    alerts, t = _feed(bt, "a", [False] * 9)
    assert alerts == [] and bt.firing() == []
    # the 10th miss crosses the floor with both windows saturated
    a = bt.observe("a", False, t)
    assert a is not None and a.kind == "fire" and bt.firing() == ["a"]
    assert a.burn_long >= 1.0 and a.burn_short >= 1.0


def test_burn_needs_short_window_too():
    # long window full of old misses, short window clean: no alert — the
    # short window is what makes the alert reset when the cause is fixed
    bt = BurnRateTracker(objective=0.9, window=6.0, min_events=5)
    _feed(bt, "a", [False] * 6, t0=0.0, dt=0.1)        # misses at t<0.6
    bt._firing["a"] = False                            # reset mid-test
    bt.alerts.clear()
    alerts, _ = _feed(bt, "a", [True] * 20, t0=5.0, dt=0.05)
    # short window (1s) sees only hits -> burn_short 0 -> no fire
    assert all(a.kind != "fire" for a in alerts)


def test_burn_clear_hysteresis():
    bt = BurnRateTracker(objective=0.9, window=2.0, min_events=4,
                         threshold=1.0, clear_frac=0.5)
    alerts, t = _feed(bt, "a", [False] * 6, dt=0.1)
    assert [a.kind for a in alerts] == ["fire"]
    # recovery: hits dilute the windows; the alert clears only when BOTH
    # burns drop below threshold * clear_frac, not at the first hit
    alerts2, _ = _feed(bt, "a", [True] * 40, t0=t, dt=0.1)
    kinds = [a.kind for a in alerts2]
    assert kinds == ["clear"]
    assert bt.firing() == []
    # the clear did not happen on the very first hit
    first_clear_t = alerts2[0].t
    assert first_clear_t > t + 0.05


def test_burn_tracker_exports_gauges_trace_and_flight(tmp_path):
    reg = MetricsRegistry()
    tr = Tracer()
    fr = FlightRecorder(str(tmp_path / "f.json"), capacity=8)
    bt = BurnRateTracker(objective=0.9, window=2.0, min_events=3,
                         metrics=reg, tracer=tr, flight=fr)
    _feed(bt, "vip", [False] * 4, dt=0.1)
    g = reg.snapshot()["gauges"]
    assert g["slo.burn_firing.vip"] == 1.0
    assert g["slo.burn_long.vip"] >= 1.0
    assert reg.snapshot()["counters"]["slo.burn_alerts.vip"] == 1
    names = [e["name"] for e in tr.to_dict()["traceEvents"]
             if e["ph"] == "i"]
    assert "burn-fire" in names
    dump = json.load(open(fr.dump("exception")))
    kinds = [e["kind"] for e in dump["events"]]
    assert "burn-alert" in kinds
    d = bt.to_dict()
    assert d["kind"] == "burn-report" and d["n_alerts"] == 1
    assert d["firing"] == ["vip"]


def test_burn_tracker_validation():
    with pytest.raises(ValueError, match="objective"):
        BurnRateTracker(objective=1.0)
    with pytest.raises(ValueError, match="window"):
        BurnRateTracker(window=0.0)
    assert not NULL_BURN.enabled
    assert NULL_BURN.observe("t", False, 0.0) is None


# -------------------------------------------------------------- exporter

def test_prometheus_text_shape():
    reg = MetricsRegistry()
    reg.counter("serve.slo_hit.interactive").inc(10)
    reg.gauge("serve.queue_depth").set(3)
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 9.0):
        h.observe(v)
    text = prometheus_text(reg.snapshot())
    assert "# TYPE sac_serve_slo_hit_interactive counter" in text
    assert "sac_serve_slo_hit_interactive 10" in text
    assert "# TYPE sac_serve_queue_depth gauge" in text
    assert 'sac_lat_bucket{le="0.1"} 1' in text
    assert 'sac_lat_bucket{le="1"} 2' in text          # cumulative
    assert 'sac_lat_bucket{le="+Inf"} 3' in text
    assert "sac_lat_count 3" in text
    assert text.endswith("\n")


def test_exporter_serves_metrics_and_json_on_ephemeral_port():
    reg = MetricsRegistry()
    reg.counter("pool.spawned").inc(4)
    sampler = TimeSeriesSampler(reg, interval=0.1)
    sampler.tick(0.0)
    burn = BurnRateTracker(metrics=reg)
    with MetricsExporter(reg, sampler=sampler, burn=burn, port=0) as exp:
        assert exp.port > 0
        with urllib.request.urlopen(f"{exp.url}/metrics", timeout=5) as r:
            assert "version=0.0.4" in r.headers["Content-Type"]
            text = r.read().decode()
        assert "sac_pool_spawned 4" in text
        with urllib.request.urlopen(f"{exp.url}/json", timeout=5) as r:
            doc = json.load(r)
        assert doc["kind"] == "metrics-scrape"
        assert doc["snapshot"]["counters"]["pool.spawned"] == 4
        assert doc["series"]["samples"] == 1
        assert doc["burn"]["kind"] == "burn-report"
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{exp.url}/nope", timeout=5)
        assert exp.scrapes == 2
    assert exp._server is None                 # stop() tore it down


def test_exporter_json_truncates_series_tail():
    reg = MetricsRegistry()
    reg.counter("x")
    sampler = TimeSeriesSampler(reg, interval=0.01)
    for i in range(20):
        sampler.tick(i * 0.01)
    exp = MetricsExporter(reg, sampler=sampler, series_tail=5)
    doc = exp.json_payload()
    assert len(doc["series"]["t"]) == 5
    assert len(doc["series"]["counters"]["x"]) == 5


# ------------------------------------------------- flight recorder series

def test_flight_dump_embeds_timeseries_tail(tmp_path):
    reg = MetricsRegistry()
    c = reg.counter("x")
    fr = FlightRecorder(str(tmp_path / "f.json"), capacity=8,
                        series_tail=3)
    sampler = TimeSeriesSampler(reg, interval=0.1)
    fr.bind_sampler(sampler)
    for i in range(6):
        c.inc()
        sampler.tick(i * 0.1)
    fr.record("tick")
    dump = json.load(open(fr.dump("exception")))
    assert len(dump["series"]) == 3            # tail only
    assert dump["series"][-1]["counters"]["x"] == 6
    # the null sampler never binds: no series key
    fr2 = FlightRecorder(str(tmp_path / "g.json"))
    fr2.bind_sampler(NULL_SAMPLER)
    fr2.record("tick")
    assert "series" not in json.load(open(fr2.dump("exception")))


# -------------------------------------------------- scheduler integration

def _tenants():
    return (TenantSpec("interactive", rows=16, inner=64, target_error=0.5,
                       deadline=0.02, weight=1.0),)


def test_open_loop_serve_ticks_sampler_on_virtual_clock():
    reg = MetricsRegistry()
    sampler = TimeSeriesSampler(reg, interval=0.05)
    burn = BurnRateTracker(objective=0.9, window=2.0, min_events=4,
                           metrics=reg)
    code_cfg = ServeConfig(deadlines=(1.1, 1.6), seed=7, batch_size=2,
                           queue_policy="edf", queue_limit=4,
                           shed_expired=True)
    from repro.core import LayerSACCode
    sched = MasterScheduler(LayerSACCode(4, 8, base="ortho", eps=6.25e-3),
                            SimulatedBackend(), code_cfg, metrics=reg,
                            sampler=sampler, burn=burn)
    wl = build_workload(_tenants(), rate=10.0, horizon=3.0, seed=5)
    report = run_load(sched, wl, horizon=3.0, burn=burn)
    assert len(sampler) > 5                    # the loop actually ticked
    ts = [s["t"] for s in sampler.samples()]
    assert ts == sorted(ts)                    # serving clock is monotone
    # virtual clock: the series spans the workload horizon, not wall time
    assert ts[-1] > 1.0
    ser = sampler.series()
    assert "serve.queue_depth" in ser["gauges"]
    assert "serve.inflight_shards" in ser["gauges"]
    # the 20ms deadline is unmeetable: every served request misses, so
    # the burn alert must have fired and ride the load report
    assert report.burn is not None and report.burn["n_alerts"] >= 1
    assert "interactive" in report.burn["firing"]


def test_closed_loop_serve_ticks_sampler_and_stamps_batches():
    reg = MetricsRegistry()
    sampler = TimeSeriesSampler(reg, interval=1e-6)
    from repro.core import LayerSACCode
    sched = MasterScheduler(LayerSACCode(4, 8, base="ortho", eps=6.25e-3),
                            SimulatedBackend(),
                            ServeConfig(deadlines=(1.1,), seed=7,
                                        batch_size=2),
                            metrics=reg, sampler=sampler)
    rng = np.random.default_rng(3)
    for _ in range(4):
        sched.submit(rng.standard_normal((16, 64)),
                     rng.standard_normal((64, 16)))
    results = sched.run()
    assert all(r.batch is not None for r in results)
    assert len({r.batch for r in results}) == 2
    assert len(sampler) >= 2
    ts = [s["t"] for s in sampler.samples()]
    # the global serve clock advances monotonically across batches
    assert ts == sorted(ts)
    assert reg.snapshot()["gauges"]["serve.inflight_shards"] == 0


# ----------------------------------------------------------------- CLI

def test_serve_parser_accepts_live_obs_flags():
    args = build_parser().parse_args(
        ["--sample-interval", "0.5", "--metrics-port", "0",
         "--burn-alerts", "--burn-objective", "0.95",
         "--burn-window", "10"])
    assert args.sample_interval == 0.5
    assert args.metrics_port == 0
    assert args.burn_alerts and args.burn_objective == 0.95
    d = build_parser().parse_args([])
    assert d.sample_interval is None and d.metrics_port is None
    assert not d.burn_alerts


@pytest.mark.parametrize("argv,msg", [
    (["--sample-interval", "0"], "sample-interval"),
    (["--metrics-port", "70000"], "metrics-port"),
    (["--burn-objective", "0.5"], "burn-objective"),
    (["--burn-window", "5"], "burn-window"),
    (["--burn-alerts", "--burn-objective", "1.5"], "burn-objective"),
])
def test_serve_rejects_bad_live_obs_flags(argv, msg):
    from repro.launch.serve import _collect_problems
    problems = _collect_problems(build_parser().parse_args(argv))
    assert any(msg in p for p in problems), problems


def test_run_serve_with_live_obs_stack(tmp_path):
    args = build_parser().parse_args(
        ["--backend", "sim", "--requests", "4", "--batch-size", "2",
         "--sample-interval", "0.05", "--metrics-port", "0",
         "--burn-alerts", "--json"])
    rep = run_serve(args)
    ob = rep.observability
    assert ob is not None
    assert ob["sample_interval"] == 0.05
    assert ob["samples"] >= 1
    assert ob["metrics_port"] > 0              # ephemeral port was bound
    assert ob["burn"]["objective"] == 0.9
    # request dicts carry the attribution stamps
    for r in rep.requests:
        assert "batch" in r and "arrival" in r and "t_dispatch" in r
        assert "slo_ok" in r and "tenant" in r
