"""Theorems 1 & 2: the β formulas against exact enumeration.

E‖C - βC_m‖² is quadratic in β, so the true optimum over the uniform
completion-order distribution is ``E<C, C_m> / E‖C_m‖²`` — computable exactly
for small instances by enumerating subsets.  The closed forms must match.
"""
import itertools

import numpy as np
import pytest

from repro.core import (GroupSACCode, LayerSACCode, eq5_beta, thm1_beta,
                        thm1_moments, thm2_beta, thm2_gammas, x_complex)
from repro.core.partition import block_outer_products, split_contraction


def _blocks(K, seed=0, Nx=6, bz=5, Ny=4):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((Nx, bz * K))
    B = rng.standard_normal((bz * K, Ny))
    Ab, Bb = split_contraction(A, B, K)
    return Ab, Bb, A @ B


# ---------------------------------------------------------------- Theorem 1

@pytest.mark.parametrize("K,m", [(4, 2), (5, 3), (5, 2), (6, 4)])
def test_thm1_beta_matches_enumeration(K, m):
    Ab, Bb, C = _blocks(K, seed=K * 10 + m)
    prods = block_outer_products(Ab, Bb)        # (K, Nx, Ny)
    # enumerate all prefixes == all m-subsets (uniform)
    num = den = 0.0
    for subset in itertools.combinations(range(K), m):
        Cl = prods[list(subset)].sum(axis=0)
        num += float(np.sum(C * Cl))
        den += float(np.sum(Cl * Cl))
    beta_enum = num / den
    M1, M2 = thm1_moments(prods)
    beta_formula = thm1_beta(M1, M2, m, K)
    np.testing.assert_allclose(beta_formula, beta_enum, rtol=1e-10)


def test_thm1_beta_is_argmin():
    """The formula β beats nearby βs on the enumerated objective."""
    K, m = 5, 3
    Ab, Bb, C = _blocks(K, seed=99)
    prods = block_outer_products(Ab, Bb)
    M1, M2 = thm1_moments(prods)
    b_star = thm1_beta(M1, M2, m, K)

    def expected_err(b):
        errs = [np.linalg.norm(C - b * prods[list(s)].sum(0)) ** 2
                for s in itertools.combinations(range(K), m)]
        return float(np.mean(errs))

    e_star = expected_err(b_star)
    for b in (b_star * 0.9, b_star * 1.1, 1.0, K / m):
        assert e_star <= expected_err(b) + 1e-9


def test_thm1_unbiasedness_eq10():
    """Eq. (10): (K/m)·C_l is unbiased over uniform prefixes."""
    K, m = 5, 2
    Ab, Bb, C = _blocks(K, seed=5)
    prods = block_outer_products(Ab, Bb)
    acc = np.zeros_like(C)
    subsets = list(itertools.combinations(range(K), m))
    for s in subsets:
        acc += (K / m) * prods[list(s)].sum(axis=0)
    np.testing.assert_allclose(acc / len(subsets), C, rtol=1e-10)


def test_thm1_limits():
    # M2 == 0 (orthogonal products) → β* = 1
    assert thm1_beta(10.0, 0.0, 3, 8) == pytest.approx(1.0)
    # M1 << M2 → β* → (K-1)/(m-1)
    assert thm1_beta(1e-12, 5.0, 3, 8) == pytest.approx(7 / 2, rel=1e-6)
    # m == K → β* = 1 regardless
    assert thm1_beta(3.0, 7.0, 8, 8) == pytest.approx(1.0)


# ---------------------------------------------------------------- Theorem 2

def _lsac_instance(K=3, n=2, seed=1):
    N = K * n
    code = LayerSACCode(K, N, base="lagrange", eps=1e-3)
    Ab, Bb, C = _blocks(K, seed=seed)
    ap = code.anchor_products(Ab, Bb)           # (K, Nx, Ny)
    return code, ap, C, N


@pytest.mark.parametrize("m", [1, 2, 3, 4])
def test_thm2_beta_matches_enumeration(m):
    code, ap, C, N = _lsac_instance()
    K = code.K
    alphas = code.alphas
    num = den = 0.0
    for subset in itertools.combinations(range(N), m):
        hit = np.zeros(K, bool)
        for w in subset:
            hit[code.cluster[w]] = True
        Cm = np.einsum("k,kij->ij", alphas * hit, ap)
        num += float(np.sum(C * Cm))
        den += float(np.sum(Cm * Cm))
    beta_enum = num / den
    beta_formula = thm2_beta(ap, alphas, N, m, code.n_sizes)
    np.testing.assert_allclose(beta_formula, beta_enum, rtol=1e-9)


def test_thm2_gammas_are_probabilities():
    gamma, gamma_pair = thm2_gammas(24, 8, np.full(8, 3))
    assert np.all((0 <= gamma) & (gamma <= 1))
    assert np.all(gamma_pair <= gamma[:, None] + 1e-12)   # P(i∧j) <= P(i)
    # brute-force check of γ_i for one cell
    import math
    want = 1 - math.comb(21, 8) / math.comb(24, 8)
    np.testing.assert_allclose(gamma[0], want)


def test_eq5_is_thm2_limit():
    """eq5 (corrected orientation) == Thm-2 with identical, fully-correlated
    anchor products (M̃_ij == M̃_i for all i,j)."""
    K, n, N, m = 4, 3, 12, 5
    M = np.ones((K, 2, 2))                       # all anchor products equal
    b_thm2 = thm2_beta(M, np.ones(K), N, m, np.full(K, n))
    b_eq5 = eq5_beta(N, m, K)
    # eq5 drops the M̃_i (diagonal) terms; with them included the two differ
    # slightly — check eq5 against the diagonal-free limit instead:
    gamma, gamma_pair = thm2_gammas(N, m, np.full(K, n))
    b_limit = gamma[0] / gamma_pair[0, 1]
    np.testing.assert_allclose(b_eq5, b_limit, rtol=1e-12)
    assert b_eq5 > 1.0                           # upweights missing clusters
    assert abs(b_thm2 - b_eq5) / b_eq5 < 0.25    # same regime


def test_paper_beta_values():
    """Fig. 3b uses β = 7/4 for G-SAC (K=8, K1=5) and β_8 for L-SAC."""
    # case2 β = (K-1)/(m_l-1) = 7/4
    from repro.core import group_beta
    assert group_beta("case2", 5, 8) == pytest.approx(7 / 4)
    # β_8 for N=24, K=8, n=3 (corrected eq. 5) ≈ 1.429
    assert eq5_beta(24, 8, 8) == pytest.approx(1.4291, rel=1e-3)


def test_oracle_beta_reduces_error_when_correlated():
    """Correlated blocks (λ large): oracle β beats β=1 on average (Fig. 3b)."""
    from repro.core import correlated_problem, run_trace, simulate_completion
    rng = np.random.default_rng(0)
    K, N = 8, 24
    A, B = correlated_problem(rng, lam=10.0, K=K, Nx=20, Nz=160, Ny=20)
    errs = {"one": [], "oracle": []}
    for t in range(8):
        code = GroupSACCode(K, N, x_complex(N, 0.1), [5, 3],
                            rng=np.random.default_rng(t))
        trace = simulate_completion(np.random.default_rng(100 + t), N)
        for mode in errs:
            cur = run_trace(code, A, B, trace, beta_mode=mode, ms=[8])
            errs[mode].append(cur.total[7])
    assert np.mean(errs["oracle"]) < np.mean(errs["one"])
