"""Transport seam: framing, operand shipping, and death-not-hang contracts.

The wire protocol is the part of the cluster runtime a deployment actually
trusts: length-prefixed frames must round-trip every payload size (empty
frames and multi-MiB operand blocks alike), a truncated frame or peer
disconnect must surface as :class:`TransportClosed` — which the pool turns
into a *lost shard* event, never a hang — and a batch's operand blocks must
ship at most once per (worker, batch) on the socket path while shared
memory is provably released on the local path.

The in-process round-trips drive a real :class:`SocketTransport` listener
and a real :class:`LocalTransport` pipe pair against their worker
endpoints without spawning processes; the disconnect test goes through the
full :class:`ClusterBackend` dispatch (crash chaos = ``os._exit`` mid-task,
so the master sees a raw EOF on the stream).
"""
import multiprocessing
import socket
import struct
import threading
import time
from multiprocessing import shared_memory

import numpy as np
import pytest

from repro.cluster import (LocalTransport, SocketTransport, TransportClosed,
                           make_transport)
from repro.cluster.transport import (make_worker_endpoint, recv_frame,
                                     recv_msg, send_frame, send_msg)
from repro.core import MatDotCode, x_complex


# ----------------------------------------------------------------- framing

def _pair():
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)
    return a, b


@pytest.mark.parametrize("size", [0, 1, 7, 1 << 16, (1 << 16) + 1, 1 << 21])
def test_frame_roundtrip_explicit_sizes(size):
    """Every frame size round-trips byte-exact — 0-byte frames are legal,
    and payloads past 64 KiB span multiple recv() chunks.  The sender runs
    on its own thread: frames larger than the kernel socket buffer need a
    live reader on the other end (exactly the deployment shape)."""
    a, b = _pair()
    try:
        payload = bytes(range(256)) * (size // 256) + bytes(size % 256)
        sender = threading.Thread(target=send_frame, args=(a, payload))
        sender.start()
        try:
            assert recv_frame(b) == payload
        finally:
            sender.join(timeout=5.0)
        assert not sender.is_alive()
    finally:
        a.close()
        b.close()


def test_msg_roundtrip_arrays_and_tuples():
    a, b = _pair()
    try:
        arr = np.arange(24.0).reshape(2, 3, 4) + 1j
        send_msg(a, ("done", 3, 0, 1, arr))
        kind, wid, bid, shard, got = recv_msg(b)
        assert (kind, wid, bid, shard) == ("done", 3, 0, 1)
        np.testing.assert_array_equal(got, arr)
    finally:
        a.close()
        b.close()


def test_frame_roundtrip_property_random_sizes():
    """Property (hypothesis): any sequence of message sizes — 0-byte and
    >64 KiB included — round-trips in order through both transports' wire
    formats: the socket frame stream and the local duplex pipe."""
    st = pytest.importorskip("hypothesis.strategies")
    hypothesis = pytest.importorskip("hypothesis")

    sizes_st = st.lists(
        st.one_of(st.integers(0, 512), st.just(0),
                  st.integers((1 << 16) + 1, (1 << 16) + 4096)),
        min_size=1, max_size=4)

    @hypothesis.given(sizes=sizes_st, seed=st.integers(0, 2**32 - 1))
    @hypothesis.settings(max_examples=25, deadline=None)
    def check(sizes, seed):
        rng = np.random.default_rng(seed)
        payloads = [rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()
                    for n in sizes]
        a, b = _pair()
        try:
            for p in payloads:
                send_frame(a, p)
            assert [recv_frame(b) for _ in payloads] == payloads
        finally:
            a.close()
            b.close()
        parent, child = multiprocessing.get_context("spawn").Pipe()
        try:
            for p in payloads:
                parent.send(("task", p))
            assert [child.recv()[1] for _ in payloads] == payloads
        finally:
            parent.close()
            child.close()

    check()


def test_truncated_header_and_frame_raise_closed_not_hang():
    header = struct.Struct("!Q")
    # peer dies mid-header
    a, b = _pair()
    a.sendall(header.pack(100)[:3])
    a.close()
    with pytest.raises(TransportClosed, match="mid-header"):
        recv_frame(b)
    b.close()
    # peer dies mid-frame: header promises 100 bytes, only 10 arrive
    a, b = _pair()
    a.sendall(header.pack(100) + b"x" * 10)
    a.close()
    with pytest.raises(TransportClosed, match="mid-frame"):
        recv_frame(b)
    b.close()
    # clean EOF between frames is still a closure, reported as such
    a, b = _pair()
    a.close()
    with pytest.raises(TransportClosed, match="peer closed"):
        recv_frame(b)
    b.close()


def test_hostile_length_prefix_and_garbage_pickle_raise_closed():
    a, b = _pair()
    try:
        a.sendall(struct.Struct("!Q").pack(1 << 62))
        with pytest.raises(TransportClosed, match="exceeds cap"):
            recv_frame(b)
    finally:
        a.close()
        b.close()
    a, b = _pair()
    try:
        send_frame(a, b"not a pickle")
        with pytest.raises(TransportClosed, match="undecodable"):
            recv_msg(b)
    finally:
        a.close()
        b.close()


# ----------------------------------------- in-process transport round-trips

def test_socket_transport_roundtrip_ships_operands_once():
    """Full master<->worker conversation over real TCP, one process: ready
    handshake identifies the dialer, the operands frame rides the stream
    exactly once ahead of the first task that references it, results land
    on the shared queue, and an endpoint close marks the channel dead."""
    tr = SocketTransport(hosts=("127.0.0.1",))
    ep = None
    try:
        chan, arg = tr.connect(0)
        assert arg[0] == "socket"
        ep = make_worker_endpoint(arg)
        ep.send(("ready", 0))
        assert chan.poll_ready(5.0)
        E_A = np.arange(24.0).reshape(2, 3, 2, 2) + 0.5j
        E_B = np.arange(24.0).reshape(2, 3, 2, 2) - 1.0
        h = tr.publish(E_A, E_B)
        assert tr.live_operands == 1
        assert chan.send(("task", 7, 0, h.ref), operands=h)
        assert chan.send(("task", 7, 1, h.ref), operands=h)
        assert ep.recv() == ("task", 7, 0, h.ref)   # operand frame consumed
        got_A, got_B = ep.get_operands(h.ref)
        np.testing.assert_array_equal(got_A, E_A)
        np.testing.assert_array_equal(got_B, E_B)
        assert ep.recv() == ("task", 7, 1, h.ref)   # not shipped twice
        ep.send(("done", 0, 7, 0, got_A[:, 0]))
        kind, wid, bid, shard, P = tr.results.get(timeout=5.0)
        assert (kind, wid, bid, shard) == ("done", 0, 7, 0)
        np.testing.assert_array_equal(P, E_A[:, 0])
        h.release()
        assert tr.live_operands == 0
        ep.close()
        deadline = time.monotonic() + 5.0
        while not chan.dead and time.monotonic() < deadline:
            time.sleep(0.01)
        assert chan.dead                            # EOF → liveness sweep
    finally:
        if ep is not None:
            ep.close()
        tr.close()


def test_local_transport_roundtrip_and_shm_released():
    """Same conversation over the pipe/shm plumbing — and the operand
    blocks are *provably* unlinked on release: re-attaching by name fails."""
    ctx = multiprocessing.get_context("spawn")
    tr = make_transport("local", ctx=ctx)
    assert isinstance(tr, LocalTransport)
    chan, arg = tr.connect(0)
    ep = make_worker_endpoint(arg)
    try:
        ep.send(("ready", 0))
        assert chan.poll_ready(5.0)
        E_A = np.arange(24.0).reshape(2, 3, 2, 2) + 0.5j
        E_B = np.arange(24.0).reshape(2, 3, 2, 2) - 1.0
        h = tr.publish(E_A, E_B)
        token = h.token                             # == shm_a's name
        assert chan.send(("task", 7, 0, h.ref), operands=h)
        assert ep.recv() == ("task", 7, 0, h.ref)
        got_A, got_B = ep.get_operands(h.ref)
        np.testing.assert_array_equal(got_A, E_A)
        np.testing.assert_array_equal(got_B, E_B)
        ep.send(("done", 0, 7, 0, np.ascontiguousarray(got_A[:, 0])))
        kind, wid, bid, shard, P = tr.results.get(timeout=5.0)
        assert (kind, wid, bid, shard) == ("done", 0, 7, 0)
        np.testing.assert_array_equal(P, E_A[:, 0])
        ep.release_operands()                       # worker detaches
        h.release()                                 # master unlinks
        assert tr.live_operands == 0
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=token)
    finally:
        ep.close()
        chan.close()
        tr.close()


def test_make_transport_rejects_unknown_name():
    with pytest.raises(ValueError, match="unknown transport .*valid: local, socket"):
        make_transport("carrier-pigeon")


# ------------------------------------------------- disconnect => lost shard

def test_peer_disconnect_reports_shard_lost_not_hung():
    """A worker whose stream dies mid-task (``os._exit`` on crash chaos —
    the master sees raw EOF, no farewell message) resolves as a lost-shard
    event in bounded wall-clock; the surviving shards all complete."""
    from repro.cluster.backend import ClusterBackend
    t0 = time.monotonic()
    code = MatDotCode(2, 4, x_complex(4, 0.1))
    rng = np.random.default_rng(13)
    As = [rng.standard_normal((8, 8)) for _ in range(2)]
    Bs = [rng.standard_normal((8, 8)) for _ in range(2)]
    with ClusterBackend(workers=4, chaos="crash:1", seed=0,
                        transport="socket") as be:
        d = be.dispatch_batch(code, As, Bs)
        d.set_abandon(20.0)
        done = []
        while d.outstanding:
            ev = d.next_event(timeout=5.0)
            if ev is None:
                break
            if ev.kind == "done":
                done.append(ev.shard)
        d.finalize()
    assert d.lost == {0: "crash"}
    assert sorted(done) == [1, 2, 3]
    assert time.monotonic() - t0 < 30.0
