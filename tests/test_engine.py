"""Batched Monte-Carlo engine: equivalence with the legacy per-trial path.

The acceptance bar: the engine reproduces ``run_trace`` / ``average_curves``
to ≤1e-10 *relative* error on every curve entry, for MatDot, OrthoMatDot,
LayerSAC and GroupSAC, across both completion models.  Entries at the f64
noise floor (normalized error below 1e-15 — exact-recovery residuals whose
value is itself rounding noise) are compared absolutely; everything above it
must match relatively.
"""
import numpy as np
import pytest

from repro.core import (CompletionBatch, GroupSACCode, LayerSACCode,
                        MatDotCode, OrthoMatDotCode, ProblemContext,
                        SimulationEngine, average_curves,
                        average_curves_reference, extraction_weights,
                        extraction_weights_batch, run_trace,
                        run_trace_reference, simulate_completion,
                        simulate_completion_batch, x_complex)

K, N = 4, 12
RTOL, ATOL = 1e-10, 1e-15


def _problem(seed=2):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((40, 320)), rng.standard_normal((320, 30))


def _factories():
    return {
        "matdot": lambda r: MatDotCode(K, N, x_complex(N, 0.1)),
        "orthomatdot": lambda r: OrthoMatDotCode(K, N),
        "layer_sac": lambda r: LayerSACCode(K, N, base="ortho", eps=1e-2),
        "group_sac": lambda r: GroupSACCode(K, N, x_complex(N, 0.1), [2, 2],
                                            rng=r),
    }


def _assert_curves_equal(ref, eng, rtol=RTOL, atol=ATOL):
    for attr in ("total", "approx", "comp"):
        r, e = getattr(ref, attr), getattr(eng, attr)
        assert np.array_equal(np.isnan(r), np.isnan(e)), attr
        ok = ~np.isnan(r)
        np.testing.assert_allclose(e[ok], r[ok], rtol=rtol, atol=atol,
                                   err_msg=attr)


# ------------------------------------------------------------- equivalence

@pytest.mark.parametrize("name", ["matdot", "orthomatdot", "layer_sac",
                                  "group_sac"])
@pytest.mark.parametrize("model", ["uniform", "shifted_exp"])
def test_average_curves_matches_reference(name, model):
    A, B = _problem()
    factory = _factories()[name]
    ref = average_curves_reference(factory, A, B, trials=10, seed=3,
                                   completion_model=model)
    eng = average_curves(factory, A, B, trials=10, seed=3,
                         completion_model=model)
    _assert_curves_equal(ref, eng)


@pytest.mark.parametrize("name", ["matdot", "orthomatdot", "layer_sac",
                                  "group_sac"])
def test_run_trace_matches_reference(name):
    A, B = _problem()
    rng = np.random.default_rng(7)
    code = _factories()[name](rng)
    for _ in range(3):
        trace = simulate_completion(rng, code.N)
        ref = run_trace_reference(code, A, B, trace)
        eng = run_trace(code, A, B, trace)
        _assert_curves_equal(ref, eng)


def test_oracle_beta_and_ms_subset_equivalence():
    A, B = _problem()
    factory = _factories()["group_sac"]
    ref = average_curves_reference(factory, A, B, trials=6, seed=5,
                                   beta_mode="oracle", ms=[2, 5, 7])
    eng = average_curves(factory, A, B, trials=6, seed=5,
                         beta_mode="oracle", ms=[2, 5, 7])
    _assert_curves_equal(ref, eng)


@pytest.mark.parametrize("products", ["direct", "cross"])
def test_products_modes_match_reference(products):
    A, B = _problem()
    factory = _factories()["group_sac"]
    ref = average_curves_reference(factory, A, B, trials=8, seed=11)
    eng = average_curves(factory, A, B, trials=8, seed=11, products=products)
    _assert_curves_equal(ref, eng)


# ------------------------------------------------------------- gram norms

def test_gram_norms_match_above_noise_floor():
    A, B = _problem()
    rng = np.random.default_rng(9)
    code = LayerSACCode(K, N, base="ortho", eps=1e-2)
    batch = simulate_completion_batch(rng, N, 16)
    exact = SimulationEngine(code, A, B).run_batch(batch)
    gram = SimulationEngine(code, A, B, norms="gram").run_batch(batch)
    for attr in ("total", "approx", "comp"):
        r, e = getattr(exact, attr), getattr(gram, attr)
        assert np.array_equal(np.isnan(r), np.isnan(e))
        ok = ~np.isnan(r) & (np.abs(r) > 1e-8)      # above the gram floor
        np.testing.assert_allclose(e[ok], r[ok], rtol=1e-7, err_msg=attr)


# ------------------------------------------------------------- jax backend

def test_jax_backend_agrees_with_numpy():
    A, B = _problem()
    rng = np.random.default_rng(4)
    batch = simulate_completion_batch(rng, N, 6)
    for code in (LayerSACCode(K, N, base="ortho", eps=1e-2),
                 GroupSACCode(K, N, x_complex(N, 0.1), [2, 2])):
        c_np = SimulationEngine(code, A, B).run_batch(batch)
        c_jx = SimulationEngine(code, A, B, backend="jax").run_batch(batch)
        for attr in ("total", "approx", "comp"):
            r, e = getattr(c_np, attr), getattr(c_jx, attr)
            assert np.array_equal(np.isnan(r), np.isnan(e))
            ok = ~np.isnan(r)
            # scoped-x64 jax path: f64 fidelity, only summation order differs
            np.testing.assert_allclose(e[ok], r[ok], rtol=1e-8, atol=1e-14,
                                       err_msg=f"{code.name}/{attr}")


def test_jax_backend_leaves_global_precision_alone():
    import jax
    import jax.numpy as jnp
    before = bool(jax.config.jax_enable_x64)
    A, B = _problem()
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(0)
    SimulationEngine(code, A, B, backend="jax").run_batch(
        simulate_completion_batch(rng, N, 2))
    assert bool(jax.config.jax_enable_x64) == before
    if not before:
        assert jnp.asarray(np.float64(1.0)).dtype == jnp.float32


# -------------------------------------------------------- batched plumbing

def test_simulate_completion_batch_shapes_and_validity():
    rng = np.random.default_rng(1)
    b = simulate_completion_batch(rng, 9, 5)
    assert b.orders.shape == (5, 9) and b.times is None
    for row in b.orders:
        assert sorted(row) == list(range(9))
    b = simulate_completion_batch(rng, 9, 5, model="shifted_exp",
                                  straggler_frac=0.3)
    assert b.times.shape == (5, 9)
    for row, t in zip(b.orders, b.times):
        assert np.array_equal(row, np.argsort(t, kind="stable"))
    tr = b.trace(2)
    assert np.array_equal(tr.order, b.orders[2])
    rt = CompletionBatch.from_traces([b.trace(i) for i in range(5)])
    assert np.array_equal(rt.orders, b.orders)
    assert np.array_equal(rt.times, b.times)


def test_extraction_weights_batch_matches_scalar():
    rng = np.random.default_rng(6)
    for m, p in [(7, 7), (9, 6)]:
        V = rng.standard_normal((5, m, p)) + 1j * rng.standard_normal((5, m, p))
        a = rng.standard_normal(p)
        W = extraction_weights_batch(V, a)
        for t in range(5):
            np.testing.assert_allclose(W[t], extraction_weights(V[t], a),
                                       rtol=1e-9, atol=1e-12)


def test_problem_context_reuse():
    A, B = _problem()
    ctx = ProblemContext.build(A, B, K)
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(8)
    batch = simulate_completion_batch(rng, N, 4)
    with_ctx = SimulationEngine(code, A, B, problem=ctx).run_batch(batch)
    without = SimulationEngine(code, A, B).run_batch(batch)
    _assert_curves_equal(without, with_ctx)
    cross = ctx.cross_products()
    np.testing.assert_allclose(
        np.einsum("kkij->kij", cross), ctx.block_products, rtol=1e-12)


def test_run_trace_full_length_and_thresholds():
    A, B = _problem()
    code = LayerSACCode(K, N, base="ortho", eps=1e-2)
    rng = np.random.default_rng(3)
    cur = run_trace(code, A, B, simulate_completion(rng, N))
    assert cur.ms.shape == (N,) and cur.total.shape == (N,)
    assert not np.isnan(cur.total).any()            # L-SAC estimates from m=1
    code2 = MatDotCode(K, N, x_complex(N, 0.1))
    cur2 = run_trace(code2, A, B, simulate_completion(rng, N))
    R = code2.recovery_threshold
    assert np.isnan(cur2.total[:R - 1]).all()
    assert not np.isnan(cur2.total[R - 1:]).any()
