"""Shared test fixtures/paths.

``tools/`` holds standalone scripts (no package), but their logic —
trace validation, the sac_top dashboard/attribution CLI — is under test;
put the directory on the import path so tests import them by module name.
"""
import os
import sys

_TOOLS = os.path.join(os.path.dirname(os.path.dirname(__file__)), "tools")
if _TOOLS not in sys.path:
    sys.path.insert(0, _TOOLS)
