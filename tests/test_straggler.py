"""Straggler-model generators: vectorized draws and the scenario widening.

The batched straggler-subset draw replaced a per-trial ``rng.choice`` loop;
its per-row subsets must stay uniform k-subsets (equivalence in
*distribution* — the streams differ by construction), pinned here with
seeded frequency checks against the legacy loop.
"""
import numpy as np
import pytest

from repro.core.straggler import (LATENCY_MODELS, bursty_times,
                                  bursty_times_batch, heterogeneous_exp_times,
                                  heterogeneous_exp_times_batch,
                                  heterogeneous_fleet, sample_times,
                                  sample_times_batch, shifted_exp_times,
                                  shifted_exp_times_batch,
                                  simulate_completion,
                                  simulate_completion_batch,
                                  validate_latency_kw)


def _legacy_straggler_batch(rng, N, trials, *, shift=1.0, rate=1.0,
                            straggler_frac=0.0, straggler_slowdown=5.0):
    """The pre-vectorization implementation, verbatim (ground truth)."""
    t = shift + rng.exponential(1.0 / rate, size=(trials, N))
    if straggler_frac > 0:
        k = int(round(straggler_frac * N))
        rows = np.repeat(np.arange(trials), k)
        cols = np.concatenate([rng.choice(N, size=k, replace=False)
                               for _ in range(trials)]) if k else rows[:0]
        t[rows, cols] *= straggler_slowdown
    return t


# --------------------------------------------------- vectorized subset draw

def test_batch_straggler_rows_have_exact_subset_size():
    rng = np.random.default_rng(3)
    N, trials, frac, slow = 20, 64, 0.25, 7.0
    t = shifted_exp_times_batch(rng, N, trials, straggler_frac=frac,
                                straggler_slowdown=slow)
    # every row must have exactly round(frac*N) distinct slowed workers;
    # slowed entries are >= shift * slowdown only statistically, so recompute
    # via the base draw with the same seed
    base = np.random.default_rng(3).exponential(1.0, size=(trials, N)) + 1.0
    slowed = ~np.isclose(t, base)
    assert (slowed.sum(axis=1) == round(frac * N)).all()
    np.testing.assert_allclose(t[slowed], base[slowed] * slow)


def test_batch_straggler_distribution_matches_legacy_loop():
    """Seeded pin: the one-permutation draw is distributed like the
    per-trial ``rng.choice`` loop (uniform k-subsets, same marginals)."""
    N, trials, frac = 12, 4000, 0.25
    k = round(frac * N)
    new = shifted_exp_times_batch(np.random.default_rng(11), N, trials,
                                  straggler_frac=frac)
    old = _legacy_straggler_batch(np.random.default_rng(11), N, trials,
                                  straggler_frac=frac)
    # per-worker straggle frequency ~ Binomial(trials, k/N)/trials: uniform
    # k-subsets put every worker at p = k/N.  Recover the slowed mask from
    # the base draw (same seed consumes the same base exponentials first).
    p = k / N
    sigma = np.sqrt(p * (1 - p) / trials)
    base = 1.0 + np.random.default_rng(11).exponential(1.0, (trials, N))
    freq_new = (~np.isclose(new, base)).mean(axis=0)
    assert np.all(np.abs(freq_new - p) < 5 * sigma)
    # pooled distributions agree: matching quantiles well inside MC noise
    qs = [0.1, 0.25, 0.5, 0.75, 0.9, 0.99]
    np.testing.assert_allclose(np.quantile(new, qs), np.quantile(old, qs),
                               rtol=0.08)
    np.testing.assert_allclose(new.mean(), old.mean(), rtol=0.03)


def test_batch_straggler_zero_k_is_noop():
    rng = np.random.default_rng(0)
    a = shifted_exp_times_batch(rng, 10, 5, straggler_frac=0.01)  # k rounds to 0
    b = shifted_exp_times_batch(np.random.default_rng(0), 10, 5)
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------------- new fleet models

def test_heterogeneous_fleet_slow_class():
    shifts, rates = heterogeneous_fleet(20, slow_frac=0.25, slow_shift=4.0,
                                        slow_rate=0.25)
    assert (shifts[:5] == 4.0).all() and (shifts[5:] == 1.0).all()
    assert (rates[:5] == 0.25).all() and (rates[5:] == 1.0).all()


def test_heterogeneous_batch_matches_single_in_distribution():
    N = 16
    single = np.stack([heterogeneous_exp_times(
        np.random.default_rng([7, i]), N, slow_frac=0.25)
        for i in range(3000)])
    batch = heterogeneous_exp_times_batch(np.random.default_rng(8), N, 3000,
                                          slow_frac=0.25)
    np.testing.assert_allclose(single.mean(axis=0), batch.mean(axis=0),
                               rtol=0.12)
    # slow class means dominate fast class means in both
    for t in (single, batch):
        assert t[:, :4].mean() > 2.5 * t[:, 4:].mean()


def test_bursty_burst_hits_whole_subsets():
    N, trials = 10, 2000
    t = bursty_times_batch(np.random.default_rng(5), N, trials,
                           burst_prob=0.3, burst_frac=0.4,
                           burst_slowdown=50.0)
    # slowdown 50 on shift-1 exponentials: burst rows are unambiguous
    burst_rows = (t > 25.0).sum(axis=1)
    frac_burst = (burst_rows > 0).mean()
    assert 0.2 < frac_burst < 0.4                 # ~burst_prob of the jobs
    assert burst_rows.max() <= round(0.4 * N)     # never more than the subset
    single = np.stack([bursty_times(np.random.default_rng([9, i]), N,
                                    burst_prob=0.3, burst_frac=0.4,
                                    burst_slowdown=50.0)
                       for i in range(2000)])
    s_frac = ((single > 25.0).sum(axis=1) > 0).mean()
    assert abs(s_frac - frac_burst) < 0.06


def test_sample_times_dispatch_and_unknown_model():
    rng = np.random.default_rng(1)
    for model in LATENCY_MODELS:
        assert sample_times(rng, 8, model=model).shape == (8,)
        assert sample_times_batch(rng, 8, 5, model=model).shape == (5, 8)
    with pytest.raises(ValueError, match="unknown latency model"):
        sample_times(rng, 8, model="nope")
    with pytest.raises(ValueError, match="unknown latency model"):
        sample_times_batch(rng, 8, 5, model="nope")
    # completion-model callers keep "uniform" in their known list
    with pytest.raises(ValueError, match="uniform"):
        simulate_completion(rng, 8, model="unifrom")
    with pytest.raises(ValueError, match="uniform"):
        simulate_completion_batch(rng, 8, 5, model="unifrom")


def test_validate_latency_kw_catches_typos():
    with pytest.raises(ValueError, match="straggler_frc"):
        validate_latency_kw("shifted_exp", {"straggler_frc": 0.2})
    validate_latency_kw("shifted_exp", {"straggler_frac": 0.2})
    validate_latency_kw("heterogeneous", {"slow_frac": 0.3})
    validate_latency_kw("heterogeneous", {"shifts": [1.0], "rates": [1.0]})
    with pytest.raises(ValueError, match="burst_probb"):
        validate_latency_kw("bursty", {"burst_probb": 0.1})
    with pytest.raises(ValueError, match="unknown latency model"):
        validate_latency_kw("nope", {})


@pytest.mark.parametrize("model", ["heterogeneous", "bursty"])
def test_simulate_completion_new_models(model):
    rng = np.random.default_rng(2)
    tr = simulate_completion(rng, 9, model=model)
    assert sorted(tr.order) == list(range(9)) and tr.times.shape == (9,)
    b = simulate_completion_batch(rng, 9, 6, model=model)
    assert b.orders.shape == (6, 9) and b.times.shape == (6, 9)
    for row, t in zip(b.orders, b.times):
        assert np.array_equal(row, np.argsort(t, kind="stable"))


def test_shifted_exp_single_unchanged():
    """The single-draw path is untouched — seeded draws stay stable."""
    t = shifted_exp_times(np.random.default_rng(4), 6)
    ref = 1.0 + np.random.default_rng(4).exponential(1.0, size=6)
    np.testing.assert_array_equal(t, ref)
