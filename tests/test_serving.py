"""Serving runtime: incremental decode equivalence, cache, scheduler, CLI.

The load-bearing test is the equivalence matrix: on every (code, m) state —
including straggler-heavy completion orders — the incremental decoder must
match a from-scratch ``code.decode`` to ≤1e-10 relative.  With a cold cache
the resolve path is bit-identical by construction; the rank-1 cluster path
differs only by float64 summation order.
"""
import numpy as np
import pytest

from repro.core import (CompletionTrace, EpsApproxMatDotCode, GroupSACCode,
                        LayerSACCode, MatDotCode, chebyshev_roots,
                        simulate_completion, split_contraction, x_complex)
from repro.serving import (DecodeWeightCache, IncrementalDecoder,
                           MasterScheduler, RecomputeDecoder, ServeConfig,
                           SimulatedBackend, make_decoder, serve_request)

RNG = np.random.default_rng(42)
K, N = 8, 24


def serving_code_matrix():
    xc = x_complex(N, 0.1)
    return {
        "matdot": MatDotCode(K, N, xc),
        "eps_matdot": EpsApproxMatDotCode(K, N, xc),
        "gsac_5_3": GroupSACCode(K, N, xc, [5, 3]),
        "gsac_4_4": GroupSACCode(K, N, xc, [4, 4],
                                 rng=np.random.default_rng(3)),
        "lsac_ortho": LayerSACCode(K, N, base="ortho", eps=6.25e-3),
        "lsac_lagrange": LayerSACCode(K, N, base="lagrange", eps=3.33e-2),
    }


def traces_for(code, rng):
    """Uniform, straggler-heavy, and adversarial completion orders."""
    out = [simulate_completion(rng, code.N, model="uniform"),
           simulate_completion(rng, code.N, model="shifted_exp",
                               straggler_frac=0.3)]
    # adversarial: the N-R slowest slots all land on the lowest worker ids
    out.append(CompletionTrace(order=np.arange(code.N)[::-1], times=None))
    return out


# ------------------------------------------------------------ bug regressions

def test_time_of_zero_regression():
    """time_of(0) is the dispatch instant, not the slowest worker's time."""
    times = np.array([3.0, 1.0, 2.0])
    tr = CompletionTrace(order=np.argsort(times), times=times)
    assert tr.time_of(0) == 0.0
    assert tr.time_of(1) == 1.0
    assert tr.time_of(3) == 3.0
    no_times = CompletionTrace(order=np.arange(3), times=None)
    assert no_times.time_of(0) == 0.0
    with pytest.raises(ValueError):
        tr.time_of(4)
    with pytest.raises(ValueError):
        tr.time_of(-1)


def test_decode_weight_vector_complex_raises():
    """Complex decode weights must not silently enter the real job path."""
    from repro.runtime.coded import decode_weight_vector
    code = MatDotCode(3, 8, x_complex(8, 0.1))
    with pytest.raises(ValueError, match="complex decode weights"):
        decode_weight_vector(code, np.arange(8), 5)
    # real-point codes keep working and return real dtype
    real = MatDotCode(3, 8, chebyshev_roots(8))
    w = decode_weight_vector(real, np.arange(8), 5)
    assert not np.iscomplexobj(w)


def test_layer_sac_no_estimate_at_zero_completions():
    """decode(m=0) must be None, not an empty weighted sum (zero matrix)."""
    code = LayerSACCode(4, 8, base="ortho")
    P = code.run_workers(RNG.standard_normal((8, 16)),
                         RNG.standard_normal((16, 8)))
    assert code.estimate_weights(np.array([], dtype=int), 0) is None
    assert code.decode(P, np.arange(8), 0) is None
    assert code.estimate_weights_batch(np.arange(8)[None], 0) is None


# --------------------------------------------------------- decode equivalence

def test_incremental_matches_from_scratch_decode():
    """≤1e-10 relative on every (code, m) state, straggler-heavy included."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((40, 400))
    B = rng.standard_normal((400, 40))
    for name, code in serving_code_matrix().items():
        P = code.run_workers(A, B)
        for trace in traces_for(code, rng):
            dec = IncrementalDecoder(code)
            for m in range(1, code.N + 1):
                w = int(trace.order[m - 1])
                dec.push(w, P[w])
                got = dec.estimate()
                want = code.decode(P, trace.order, m)
                assert (got is None) == (want is None), (name, m)
                if want is None:
                    continue
                rel = np.linalg.norm(got - want) / np.linalg.norm(want)
                assert rel <= 1e-10, f"{name} m={m}: rel {rel:.2e}"


def test_incremental_matches_decode_with_beta_modes():
    """β-rescaled paths (incl. the data-dependent oracle β) agree too."""
    rng = np.random.default_rng(1)
    A = rng.standard_normal((24, 240))
    B = rng.standard_normal((240, 24))
    cases = [(GroupSACCode(K, N, x_complex(N, 0.1), [5, 3]), "unbiased"),
             (LayerSACCode(K, N, base="ortho", eps=6.25e-3), "oracle")]
    for code, beta_mode in cases:
        A_blocks, B_blocks = split_contraction(A, B, code.K)
        oracle = code.oracle_context(A_blocks, B_blocks)
        P = code.run_workers(A, B)
        trace = simulate_completion(rng, code.N, model="shifted_exp",
                                    straggler_frac=0.25)
        dec = IncrementalDecoder(code, beta_mode=beta_mode, oracle=oracle)
        for m in range(1, code.N + 1):
            w = int(trace.order[m - 1])
            dec.push(w, P[w])
            got = dec.estimate()
            want = code.decode(P, trace.order, m, beta_mode, oracle)
            assert (got is None) == (want is None)
            if want is not None:
                rel = np.linalg.norm(got - want) / np.linalg.norm(want)
                assert rel <= 1e-10, f"{code.name} m={m}: rel {rel:.2e}"


def test_incremental_update_mode_accounting():
    """The hooks do what they promise: frozen regimes never re-solve."""
    rng = np.random.default_rng(2)
    A = rng.standard_normal((16, 160))
    B = rng.standard_normal((160, 16))
    eps = EpsApproxMatDotCode(K, N, x_complex(N, 0.1))
    P = eps.run_workers(A, B)
    dec = IncrementalDecoder(eps)
    for m in range(1, N + 1):
        dec.push(int(m - 1), P[m - 1])
        dec.estimate()
    # one solve at the layer (m=K), one at exact recovery (m=R), none else
    assert dec.stats["resolve"] == 2
    assert dec.stats["rank1"] == 0

    lsac = LayerSACCode(K, N, base="ortho", eps=6.25e-3)
    P = lsac.run_workers(A, B)
    dec = IncrementalDecoder(lsac)
    for m in range(1, N + 1):
        dec.push(int(m - 1), P[m - 1])
        dec.estimate()
    R = lsac.recovery_threshold
    assert dec.stats["rank1"] == R - 1          # every pre-exact completion
    assert dec.stats["resolve"] == 1            # the exact fit only
    assert dec.stats["reuse"] == N - R          # frozen past R


def test_incremental_weight_vector_matches_runtime():
    """weight_vector() is decode_weight_vector at the decoder's state."""
    from repro.runtime.coded import decode_weight_vector
    code = GroupSACCode(4, 10, chebyshev_roots(10) * 0.3, [2, 2])
    A = RNG.standard_normal((6, 16))
    B = RNG.standard_normal((16, 5))
    P = code.run_workers(A, B)
    order = RNG.permutation(10)
    dec = IncrementalDecoder(code)
    for m in range(1, 11):
        dec.push(int(order[m - 1]), P[order[m - 1]])
        wv = dec.weight_vector()
        if m < code.first_threshold:
            assert wv is None
            continue
        want = decode_weight_vector(code, order, m)
        np.testing.assert_allclose(wv, want, rtol=1e-12, atol=1e-12)
        # the weighted sum over ALL products is the estimate
        est = np.einsum("n,nij->ij", wv, P)
        np.testing.assert_allclose(est, dec.estimate(), rtol=1e-9,
                                   atol=1e-12)


def test_cluster_weight_vector_matches_runtime():
    from repro.runtime.coded import decode_weight_vector
    code = LayerSACCode(4, 12, base="ortho", eps=1e-2)
    order = RNG.permutation(12)
    P = code.run_workers(RNG.standard_normal((8, 16)),
                         RNG.standard_normal((16, 8)))
    dec = IncrementalDecoder(code)
    for m in range(1, 13):
        dec.push(int(order[m - 1]), P[order[m - 1]])
        np.testing.assert_allclose(dec.weight_vector(),
                                   decode_weight_vector(code, order, m),
                                   rtol=1e-12, atol=1e-12)


# ----------------------------------------------------------------- LRU cache

def test_decode_weight_cache_hits_and_eviction():
    code = MatDotCode(4, 10, chebyshev_roots(10))
    P = code.run_workers(RNG.standard_normal((12, 32)),
                         RNG.standard_normal((32, 8)))
    cache = DecodeWeightCache(maxsize=2)
    base = np.arange(10)
    dec1 = IncrementalDecoder(code, cache=cache)
    for n in base:
        dec1.push(int(n), P[n])
    est1 = dec1.estimate()
    assert cache.misses == 1 and cache.hits == 0
    # same completed set, different completion order → hit, same estimate
    perm = np.concatenate([np.random.default_rng(5).permutation(7), [7, 8, 9]])
    dec2 = IncrementalDecoder(code, cache=cache)
    for n in perm:
        dec2.push(int(n), P[n])
    est2 = dec2.estimate()
    assert cache.hits == 1 and dec2.stats["cache_hit"] == 1
    rel = np.linalg.norm(est2 - est1) / np.linalg.norm(est1)
    assert rel <= 1e-8
    # eviction: fill beyond maxsize
    for key in [("a",), ("b",), ("c",)]:
        cache.put(key, (np.zeros(1), None))
    assert len(cache) == 2
    assert cache.get(("a",)) is None            # evicted (LRU)


def test_cache_disambiguates_codes_and_states():
    cache = DecodeWeightCache()
    a = MatDotCode(3, 8, chebyshev_roots(8))
    b = MatDotCode(3, 8, chebyshev_roots(8) * 0.5)
    k1 = DecodeWeightCache.key(a, np.arange(5), 5, "one")
    k2 = DecodeWeightCache.key(b, np.arange(5), 5, "one")
    k3 = DecodeWeightCache.key(a, np.arange(5), 5, "unbiased")
    k4 = DecodeWeightCache.key(a, np.array([4, 2, 0, 1, 3]), 5, "one")
    assert len({k1, k2, k3}) == 3
    assert k1 == k4                              # order-invariant


# ------------------------------------------------------------------ scheduler

def _run_sched(decoder, seed=9, stream=False, deadlines=(1.1, 1.5, 2.0, 3.0)):
    code = GroupSACCode(K, N, x_complex(N, 0.1), [5, 3])
    cfg = ServeConfig(deadlines=deadlines, stream=stream, batch_size=3,
                      decoder=decoder, seed=seed)
    sched = MasterScheduler(code, SimulatedBackend(straggler_frac=0.2), cfg)
    rng = np.random.default_rng(seed)
    for _ in range(5):
        sched.submit(rng.standard_normal((16, 80)),
                     rng.standard_normal((80, 16)))
    return sched.run()


def test_scheduler_deterministic_and_matches_recompute_baseline():
    a = _run_sched("incremental")
    b = _run_sched("incremental")
    c = _run_sched("recompute")
    assert len(a) == len(b) == len(c) == 5
    for ra, rb, rc in zip(a, b, c):
        assert len(ra.answers) == len(rb.answers) == len(rc.answers)
        for x, y, z in zip(ra.answers, rb.answers, rc.answers):
            assert (x.t, x.m, x.rel_err) == (y.t, y.m, y.rel_err)
            assert x.m == z.m and x.exact == z.exact
            if z.rel_err is None:
                assert x.rel_err is None
            else:
                assert abs(x.rel_err - z.rel_err) <= 1e-10 * max(z.rel_err,
                                                                 1e-12)


def test_scheduler_stream_answers_and_thresholds():
    results = _run_sched("incremental", stream=True)
    code_first, code_R = 5, 15                  # gsac [5,3]: first=5, R=2K-1
    for res in results:
        events = [a for a in res.answers if a.kind == "event"]
        assert len(events) == N                 # one per completion
        ms = [a.m for a in events]
        assert ms == sorted(ms)                 # refinement is monotone
        # ttfa is the first-threshold completion instant
        first_est = next(a for a in events if a.rel_err is not None)
        assert first_est.m == code_first
        assert res.ttfa == pytest.approx(first_est.t)
        exact = next(a for a in events if a.exact)
        assert exact.m == code_R
        assert res.t_exact == pytest.approx(exact.t)
        # errors shrink to (near-)exact once R workers reported
        final = [a for a in res.answers if a.m >= code_R and
                 a.rel_err is not None]
        assert final and all(a.rel_err < 1e-6 for a in final)


def test_scheduler_batching_shares_solves():
    """Requests batched together share one latency draw → cache hits."""
    results = _run_sched("incremental")
    assert sum(r.decode_stats["cache_hit"] for r in results) > 0
    # every request still gets its own full answer set
    assert all(len(r.answers) == 4 for r in results)


def test_scheduler_mixed_shapes_and_submit_validation():
    """Batches group same-shape runs; malformed jobs fail at submit()."""
    code = MatDotCode(4, 12, chebyshev_roots(12))
    cfg = ServeConfig(deadlines=(2.0, 4.0), batch_size=4, seed=1)
    sched = MasterScheduler(code, SimulatedBackend(), cfg)
    rng = np.random.default_rng(6)
    shapes = [(8, 16), (8, 16), (12, 32), (8, 16)]
    for nx, nz in shapes:
        sched.submit(rng.standard_normal((nx, nz)),
                     rng.standard_normal((nz, nx)))
    results = sched.run()
    assert [r.req_id for r in results] == [0, 1, 2, 3]
    assert all(len(r.answers) == 2 for r in results)
    with pytest.raises(ValueError, match="divisible by K"):
        sched.submit(rng.standard_normal((8, 18)),
                     rng.standard_normal((18, 8)))
    with pytest.raises(ValueError, match="matching inner dim"):
        sched.submit(rng.standard_normal((8, 16)),
                     rng.standard_normal((20, 8)))
    with pytest.raises(ValueError, match="batch_size"):
        MasterScheduler(code, config=ServeConfig(batch_size=0))


def test_serve_request_legacy_shape():
    code = GroupSACCode(K, N, x_complex(N, 0.1), [5, 3])
    rng = np.random.default_rng(1)
    A = rng.standard_normal((16, 80))
    B = rng.standard_normal((80, 16))
    res = serve_request(code, A, B, np.random.default_rng(2),
                        deadlines=[0.5, 1.5, 3.0], straggler_frac=0.2)
    assert [dl for dl, _, _ in res] == [0.5, 1.5, 3.0]
    dl, m, err = res[0]
    assert m == 0 and err is None               # nothing completes by t=0.5
    assert res[-1][1] >= res[1][1]


# ------------------------------------------------------------------ CLI seam

def test_serve_cli_validation():
    from repro.launch.serve import build_code, validate_args
    assert validate_args("gsac_k1_5", 8, 24) == []
    msgs = validate_args("gsac_k1_5", 5, 24)
    assert msgs and "gsac_auto" in msgs[0] and "--K >= 6" in msgs[0]
    assert validate_args("matdot", 8, 10)       # N < 2K-1
    assert validate_args("lsac_ortho", 8, 20)   # K does not divide N
    assert validate_args("nope", 8, 24)
    with pytest.raises(SystemExit, match="gsac_auto"):
        build_code("gsac_k1_5", 4, 24)
    # derived group sizes work for small K
    for k in (1, 2, 3, 4, 7):
        code = build_code("gsac_auto", k, 2 * k + 1 if k > 1 else 3)
        assert code.K == k


def test_serve_cli_groups_and_upfront_validation():
    """The redesigned CLI: flags live in argument groups, every illegal
    combination is reported at once (each message naming its flag), and
    the effective config prints as one parseable JSON line."""
    import json

    from repro.launch.serve import (_collect_problems, _effective_config,
                                    build_parser)
    ap = build_parser()
    groups = {g.title for g in ap._action_groups}
    assert {"fleet", "chaos", "autotune", "speculation"} <= groups
    # a coherent cluster + speculation config raises nothing
    ok = ap.parse_args(["--backend", "cluster", "--speculate",
                        "--replicate", "2", "--chaos", "crash:1"])
    assert _collect_problems(ok) == []
    cfg = json.loads(_effective_config(ok, (1.0, 2.0)))
    assert cfg["backend"] == "cluster" and cfg["speculate"] is True
    assert cfg["replicate"] == 2 and cfg["deadlines"] == [1.0, 2.0]
    # five independent mistakes -> five messages, all in one pass
    bad = ap.parse_args(["--speculate", "--replicate", "2",
                         "--chaos", "crash:1", "--drift", "ks",
                         "--batch-size", "0"])
    problems = _collect_problems(bad)
    assert len(problems) == 5
    for flag in ("--speculate", "--replicate", "--chaos", "--drift",
                 "--batch-size"):
        assert any(flag in msg for msg in problems), flag
    # hedging knobs are rejected without --speculate, with the fix named
    loose = _collect_problems(ap.parse_args(["--hedge-threshold", "0.9",
                                             "--max-speculations", "2"]))
    assert all("--speculate" in msg for msg in loose) and len(loose) == 2


def test_make_decoder_kinds():
    code = MatDotCode(3, 8, chebyshev_roots(8))
    assert isinstance(make_decoder("incremental", code), IncrementalDecoder)
    assert isinstance(make_decoder("recompute", code,
                                   cache=DecodeWeightCache()),
                      RecomputeDecoder)
    with pytest.raises(ValueError):
        make_decoder("magic", code)


def test_decoder_push_is_idempotent_per_worker():
    """A duplicate completion — a first-wins loser's late result leaking
    past the dispatch accounting — must be ignored by both decoders: a
    second push of the same worker leaves the estimate bit-unchanged and
    is counted as ``dup_ignored``, never a second rank-1/decode update."""
    code = LayerSACCode(2, 8, base="ortho", eps=6.25e-3)
    rng = np.random.default_rng(6)
    A = rng.standard_normal((8, 16))
    B = rng.standard_normal((16, 8))
    P = code.run_workers(A, B)
    for kind in ("incremental", "recompute"):
        dec = make_decoder(kind, code)
        for n in range(code.first_threshold):
            dec.push(n, P[n])
        before = dec.estimate().copy()
        dec.push(0, P[0])                          # duplicate, mid-stream
        assert dec.stats["dup_ignored"] == 1
        assert dec.m == code.first_threshold       # nothing was ingested
        np.testing.assert_array_equal(dec.estimate(), before)
        # the remaining distinct workers still fit and decode exactly
        for n in range(code.first_threshold, code.N):
            dec.push(n, P[n])
        dec.push(1, P[1])                          # duplicate at full house
        assert dec.stats["dup_ignored"] == 2
        assert dec.m == code.N
        est = dec.estimate()
        assert np.linalg.norm(est - A @ B) / np.linalg.norm(A @ B) < 1e-10


# ------------------------------------------------------------- device backend

def test_device_backend_matches_simulated_real():
    from repro.serving import DeviceBackend
    code = MatDotCode(4, 8, chebyshev_roots(8))
    rng = np.random.default_rng(3)
    As = [rng.standard_normal((16, 32)) for _ in range(2)]
    Bs = [rng.standard_normal((32, 8)) for _ in range(2)]
    want = SimulatedBackend().compute_products(code, As, Bs)
    got = DeviceBackend(use_pallas=False).compute_products(code, As, Bs)
    assert got.shape == want.shape == (2, 8, 16, 8)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 1e-4                           # f32 device path


def test_device_backend_complex_reim_expansion():
    from repro.serving import DeviceBackend
    code = MatDotCode(3, 8, x_complex(8, 0.5))
    rng = np.random.default_rng(4)
    As, Bs = [rng.standard_normal((8, 24))], [rng.standard_normal((24, 8))]
    want = SimulatedBackend().compute_products(code, As, Bs)
    got = DeviceBackend(use_pallas=False).compute_products(code, As, Bs)
    assert np.iscomplexobj(got)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 1e-4


def test_device_decode_on_mesh_exact():
    import jax

    from repro.compat import make_mesh
    from repro.serving import DeviceBackend
    if len(jax.devices()) < 1:
        pytest.skip("no jax device")
    code = MatDotCode(3, 8, chebyshev_roots(8))
    rng = np.random.default_rng(5)
    A = rng.standard_normal((16, 48))
    B = rng.standard_normal((48, 12))
    P = code.run_workers(A, B)
    dec = IncrementalDecoder(code)
    for n in range(8):
        dec.push(n, P[n])
    mesh = make_mesh((1,), ("model",))
    est = DeviceBackend.decode_on_mesh(code, A, B, dec.weight_vector(), mesh,
                                       use_pallas=False)
    rel = np.linalg.norm(np.asarray(est) - A @ B) / np.linalg.norm(A @ B)
    assert rel < 1e-3
