"""Open-loop load harness: arrivals, admission control, queue policy, SLOs.

The load-bearing tests are the queue-policy edges the open-loop redesign
pinned down: arrival-vs-completion tie order on the merged event stream
(completions and the dispatches they trigger precede arrivals at equal
timestamps), shed-on-overload accounting (every arrival lands in exactly
one of served / shed / dropped, mirrored by the obs registry), and the
bit-identical reduction of ``run_open`` to the closed-loop ``run`` when
the queue is unlimited and every arrival is at t=0.
"""
import numpy as np
import pytest

from repro.core import LayerSACCode, MatDotCode, x_complex
from repro.obs import MetricsRegistry
from repro.serving import (ARRIVAL_PROCESSES, MasterScheduler, OpenRequest,
                           ServeConfig, SimulatedBackend, TenantSpec,
                           build_workload, bursty_arrivals, make_arrivals,
                           make_backend, make_decoder, poisson_arrivals,
                           run_load, summarize_load, trace_arrivals)


def lsac48():
    return LayerSACCode(4, 8, base="ortho", eps=6.25e-3)


def operands(rng, rows=16, inner=64):
    return (rng.standard_normal((rows, inner)),
            rng.standard_normal((inner, rows)))


def sched_for(code=None, **cfg_kw):
    cfg_kw.setdefault("deadlines", (1.1, 1.6))
    cfg_kw.setdefault("seed", 7)
    return MasterScheduler(code or lsac48(), SimulatedBackend(),
                           ServeConfig(**cfg_kw))


# ------------------------------------------------------------- arrivals
def test_poisson_arrivals_deterministic_sorted_and_rate():
    a = poisson_arrivals(np.random.default_rng(5), 10.0, 50.0)
    b = poisson_arrivals(np.random.default_rng(5), 10.0, 50.0)
    assert np.array_equal(a, b)
    assert np.all(np.diff(a) > 0) and a[0] > 0 and a[-1] < 50.0
    # 500 expected arrivals: +-5 sigma keeps this deterministic-safe
    assert 350 < a.size < 650


def test_bursty_arrivals_match_offered_load_but_clump():
    rng = np.random.default_rng(11)
    b = bursty_arrivals(rng, 10.0, 200.0, burst=6.0, dwell=2.0)
    assert np.all(np.diff(b) >= 0) and b[-1] < 200.0
    # time-average rate pinned to `rate` (2000 expected, wide tolerance)
    assert 1400 < b.size < 2600
    p = poisson_arrivals(np.random.default_rng(11), 10.0, 200.0)
    # clumping: the squared coefficient of variation of the gaps exceeds
    # the Poisson value of ~1
    def cv2(ts):
        d = np.diff(ts)
        return float(np.var(d) / np.mean(d) ** 2)
    assert cv2(b) > 1.3 > cv2(p)


def test_trace_arrivals_rescale_and_clip():
    ts = trace_arrivals(None, None, None, times=[5.0, 3.0, 4.0])
    assert np.array_equal(ts, [0.0, 1.0, 2.0])      # sorted, origin-shifted
    ts = trace_arrivals(None, 2.0, None, times=[0.0, 1.0, 3.0])
    # 3 arrivals at rate 2 span 1.5s
    assert ts[-1] == pytest.approx(1.5)
    assert trace_arrivals(None, 2.0, 1.0, times=[0.0, 1.0, 3.0]).size == 2


def test_make_arrivals_dispatches_and_validates():
    ts = make_arrivals("trace", np.random.default_rng(0), None, None,
                       times=[1.0, 2.0])
    assert ts.size == 2
    with pytest.raises(ValueError, match="offered rate must be > 0"):
        make_arrivals("poisson", np.random.default_rng(0), 0.0, 1.0)


def test_tenant_spec_validation():
    with pytest.raises(ValueError, match="rows/inner"):
        TenantSpec("t", rows=0)
    with pytest.raises(ValueError, match="target_error"):
        TenantSpec("t", target_error=0.0)
    with pytest.raises(ValueError, match="deadline"):
        TenantSpec("t", deadline=-1.0)
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("t", weight=0.0)


def test_build_workload_mixes_tenants_by_weight():
    tenants = (TenantSpec("heavy", rows=8, inner=32, weight=3.0),
               TenantSpec("light", rows=12, inner=48, weight=1.0))
    wl = build_workload(tenants, rate=20.0, horizon=30.0, seed=3)
    assert all(wl[i].arrival <= wl[i + 1].arrival
               for i in range(len(wl) - 1))
    counts = {"heavy": 0, "light": 0}
    for r in wl:
        counts[r.tenant.name] += 1
        assert r.A.shape == (r.tenant.rows, r.tenant.inner)
        assert r.B.shape == (r.tenant.inner, r.tenant.rows)
    # 3:1 weights -> ~450 vs ~150 arrivals; ratio must clearly separate
    assert counts["heavy"] > 2 * counts["light"] > 0
    # deterministic in seed
    wl2 = build_workload(tenants, rate=20.0, horizon=30.0, seed=3)
    assert [r.arrival for r in wl2] == [r.arrival for r in wl]


# --------------------------------------------------------- queue policies
def test_submit_keyword_surface_and_old_positional_surface():
    sched = sched_for()
    rng = np.random.default_rng(0)
    A, B = operands(rng)
    assert sched.submit(A, B) == 0                   # legacy surface
    rid = sched.submit(A, B, tenant="t0", arrival=1.5, deadline=4.0,
                       target=1e-2)
    assert rid == 1
    req = list(sched._queue)[1]
    assert (req.tenant, req.arrival, req.deadline, req.target) \
        == ("t0", 1.5, 4.0, 1e-2)


def test_unknown_queue_policy_and_bad_limit_rejected():
    with pytest.raises(ValueError,
                       match="unknown queue policy 'lifo'; valid: fifo, edf"):
        sched_for(queue_policy="lifo")
    with pytest.raises(ValueError, match="queue_limit must be >= 1"):
        sched_for(queue_limit=0)


def test_edf_orders_by_deadline_and_batches_class_compatible():
    rng = np.random.default_rng(1)
    A1, B1 = operands(rng, rows=8, inner=32)
    A2, B2 = operands(rng, rows=12, inner=48)
    sched = sched_for(queue_policy="edf", batch_size=2)
    sched.submit(A1, B1, tenant="slack", deadline=10.0)   # head, loose
    sched.submit(A2, B2, tenant="tight", deadline=1.0)
    sched.submit(A2, B2, tenant="tight2", deadline=5.0)   # same shape
    b1 = sched._next_batch()
    # EDF anchor = tightest deadline; fill = same-shape in deadline order
    assert [r.tenant for r in b1] == ["tight", "tight2"]
    assert [r.tenant for r in sched._next_batch()] == ["slack"]
    # FIFO control: head request anchors even with the loosest deadline
    sched = sched_for(queue_policy="fifo", batch_size=2)
    sched.submit(A1, B1, tenant="slack", deadline=10.0)
    sched.submit(A2, B2, tenant="tight", deadline=1.0)
    sched.submit(A2, B2, tenant="tight2", deadline=5.0)
    assert [r.tenant for r in sched._next_batch()] == ["slack"]


def test_shed_on_overload_accounting_matches_registry():
    registry = MetricsRegistry()
    code = lsac48()
    sched = MasterScheduler(
        code, SimulatedBackend(),
        ServeConfig(deadlines=(1.1, 1.6), seed=7, batch_size=2,
                    queue_policy="edf", queue_limit=2),
        metrics=registry)
    tenants = (TenantSpec("a", rows=16, inner=64, target_error=0.5,
                          deadline=20.0, weight=1.0),
               TenantSpec("b", rows=16, inner=64, target_error=0.5,
                          deadline=20.0, weight=1.0))
    wl = build_workload(tenants, rate=12.0, horizon=4.0, seed=5)
    report = run_load(sched, wl, horizon=4.0)
    assert report.offered == len(wl) > 0
    assert report.shed > 0                      # overload must actually shed
    assert report.served + report.shed + report.dropped == report.offered
    # queue bound respected at every sampled instant
    assert report.queue["max_depth"] <= 2
    # registry mirrors the scheduler's shed list, per tenant and total
    snap = registry.snapshot()["counters"]
    assert snap["serve.shed"] == report.shed == len(sched.shed)
    per_tenant = sum(v for k, v in snap.items()
                     if k.startswith("serve.shed."))
    assert per_tenant == report.shed
    for name, t in report.tenants.items():
        assert t["offered"] == t["served"] + t["shed"] + t["dropped"]
        assert snap.get(f"serve.shed.{name}", 0) == t["shed"]


def test_shed_expired_drops_at_dequeue_as_slo_miss():
    registry = MetricsRegistry()
    sched = MasterScheduler(
        lsac48(), SimulatedBackend(),
        ServeConfig(deadlines=(1.1, 1.6), seed=7, batch_size=1,
                    shed_expired=True),
        metrics=registry)
    rng = np.random.default_rng(2)
    A, B = operands(rng)
    ten_tight = TenantSpec("tight", rows=16, inner=64, target_error=None,
                           deadline=1e-3)
    ten_ok = TenantSpec("ok", rows=16, inner=64, target_error=None,
                        deadline=1e3)
    wl = [OpenRequest(0.0, A, B, tenant=ten_ok),
          OpenRequest(0.0, A, B, tenant=ten_tight)]
    results = sched.run_open(wl)
    assert len(results) == 2
    dropped = [r for r in results if r.dropped == "expired"]
    assert [r.tenant for r in dropped] == ["tight"]
    assert dropped[0].slo_ok is False and dropped[0].answers == []
    snap = registry.snapshot()["counters"]
    assert snap["serve.dropped_expired"] == 1
    assert snap["serve.slo_miss.tight"] == 1


def test_open_loop_reduces_bit_identically_to_closed_loop():
    rng = np.random.default_rng(3)
    reqs = [operands(rng) for _ in range(6)]
    cfg = dict(deadlines=(1.1, 1.6), batch_size=2, seed=7)
    closed = sched_for(**cfg)
    for A, B in reqs:
        closed.submit(A, B)
    r_closed = closed.run()
    r_open = sched_for(**cfg).run_open(
        [OpenRequest(0.0, A, B) for A, B in reqs])
    assert len(r_closed) == len(r_open)
    for a, b in zip(r_closed, r_open):
        assert a.req_id == b.req_id
        assert [(x.t, x.m, x.kind, x.rel_err) for x in a.answers] \
            == [(y.t, y.m, y.kind, y.rel_err) for y in b.answers]


def test_arrival_tied_with_release_sees_the_freed_queue_slot():
    """Tie rule: completions and the dispatches they trigger precede
    arrivals, so an arrival at exactly the release instant of a batch is
    admitted against the queue *after* the next dispatch freed a slot —
    while an arrival strictly before the release is shed against the full
    queue."""
    rng = np.random.default_rng(4)
    A, B = operands(rng)

    def make(extra_arrival):
        sched = sched_for(batch_size=1, queue_limit=1)
        wl = [OpenRequest(0.0, A, B, tenant="first"),
              OpenRequest(0.1, A, B, tenant="queued"),
              OpenRequest(extra_arrival, A, B, tenant="tie")]
        return sched, wl

    # discover the first batch's release instant (deterministic clock)
    probe = sched_for(batch_size=1, queue_limit=1)
    t_rel = probe.run_open([OpenRequest(0.0, A, B)])[0].t_done
    assert t_rel > 0.1

    sched, wl = make(t_rel)                    # tie with the release
    results = sched.run_open(wl)
    assert [t for t, _ in sched.shed] == []
    assert sorted(r.tenant for r in results) == ["first", "queued", "tie"]
    tie = next(r for r in results if r.tenant == "tie")
    assert tie.t_dispatch >= t_rel             # served in a later batch

    sched, wl = make(t_rel - 1e-6)             # strictly before the release
    results = sched.run_open(wl)
    assert [t for t, _ in sched.shed] == ["tie"]
    assert sorted(r.tenant for r in results) == ["first", "queued"]


def test_accuracy_slo_early_release_and_tta():
    """A loose target releases the batch early (t_target < full-batch
    time) and stamps slo_ok per deadline; run_open without track_errors
    rejects accuracy SLOs up front."""
    ten = TenantSpec("fast", rows=16, inner=64, target_error=0.9,
                     deadline=50.0)
    rng = np.random.default_rng(5)
    A, B = operands(rng)
    sched = sched_for(batch_size=1)
    results = sched.run_open([OpenRequest(0.0, A, B, tenant=ten)])
    res = results[0]
    assert res.t_target is not None and res.slo_ok is True
    assert res.tta == pytest.approx(res.t_target - res.arrival)
    # early release: the target hit before the last of the 8 shards
    full = sched_for(batch_size=1).run_open([OpenRequest(0.0, A, B)])
    assert res.t_done <= full[0].t_done
    bad = sched_for(batch_size=1, track_errors=False)
    with pytest.raises(ValueError, match="track_errors"):
        bad.run_open([OpenRequest(0.0, A, B, tenant=ten)])


def test_summarize_load_counts_and_percentiles():
    ten = TenantSpec("t", rows=16, inner=64, target_error=0.5, deadline=30.0)
    rng = np.random.default_rng(6)
    A, B = operands(rng)
    sched = sched_for(batch_size=2)
    wl = [OpenRequest(0.1 * i, A, B, tenant=ten) for i in range(4)]
    report = run_load(sched, wl, horizon=10.0)
    assert report.offered == report.served == 4
    t = report.tenants["t"]
    assert t["slo_hits"] == 4 and report.goodput == pytest.approx(0.4)
    assert 0 < t["p50_tta"] <= t["p99_tta"]
    d = report.to_dict()
    assert d["kind"] == "load-report" and d["tenants"]["t"]["served"] == 4
    with pytest.raises(ValueError, match="horizon"):
        summarize_load(sched, wl, [], horizon=0.0)


# ------------------------------------------------- unified parse surfaces
@pytest.mark.parametrize("trigger", [
    pytest.param(lambda: make_backend("gpu"), id="backend"),
    pytest.param(lambda: make_arrivals(
        "uniform", np.random.default_rng(0), 1.0, 1.0), id="arrivals"),
    pytest.param(lambda: make_decoder("magic", lsac48()), id="decoder"),
    pytest.param(lambda: sched_for(queue_policy="lifo"), id="queue-policy"),
    pytest.param(lambda: __import__(
        "repro.cluster.transport", fromlist=["make_transport"]
    ).make_transport("pigeon"), id="transport"),
    pytest.param(lambda: __import__(
        "repro.cluster.worker", fromlist=["ComputeSpec"]
    ).ComputeSpec.parse("quantum"), id="compute"),
    pytest.param(lambda: __import__(
        "repro.cluster.worker", fromlist=["ChaosSpec"]
    ).ChaosSpec.parse("meteor:1"), id="chaos"),
])
def test_parse_surfaces_share_one_error_idiom(trigger):
    """Every string-spec surface rejects with `unknown <what> '<got>';
    valid: ...` so operators always see the full menu."""
    with pytest.raises(ValueError, match=r"unknown [\w\- ]+ '[^']*'; "
                                         r"valid: "):
        trigger()


def test_arrival_processes_export_matches_registry():
    assert set(ARRIVAL_PROCESSES) == {"poisson", "bursty", "trace"}


# ---------------------------------------------------------- serve report
def test_run_serve_report_round_trips_and_renders(capsys, tmp_path):
    from repro.launch.serve import (ServeReport, _render_report,
                                    build_parser, run_serve)
    args = build_parser().parse_args(
        ["--code", "matdot", "--K", "2", "--N", "6", "--requests", "2",
         "--rows", "8", "--inner", "32", "--batch-size", "2"])
    report = run_serve(args)
    assert report.config["code"] == "matdot"
    assert report.code["R"] == 3
    assert len(report.requests) == 2
    assert report.summary["requests"] == 2
    # JSON round-trip: same object back, field for field
    clone = ServeReport.from_json(report.to_json())
    assert clone == report
    path = tmp_path / "rep.json"
    report.save(str(path))
    assert ServeReport.from_dict(
        __import__("json").loads(path.read_text())) == report
    with pytest.raises(ValueError, match="not a serve-report"):
        ServeReport.from_dict({"kind": "other"})
    # the text renderer is a pure function of the report
    _render_report(report)
    out = capsys.readouterr().out
    assert "[serve] req 0:" in out and "[serve] 2 requests in" in out


def test_serve_cli_json_flag_emits_only_the_report(capsys):
    from repro.launch.serve import ServeReport, main
    main(["--code", "matdot", "--K", "2", "--N", "6", "--requests", "1",
          "--rows", "8", "--inner", "32", "--json"])
    out = capsys.readouterr().out
    rep = ServeReport.from_json(out)          # the whole stdout is the doc
    assert rep.summary["requests"] == 1


def test_cluster_open_loop_realtime_smoke():
    """Realtime arm: wall-clock arrivals against the real worker pool."""
    ten = TenantSpec("rt", rows=8, inner=32, target_error=0.8, deadline=5.0)
    wl = build_workload((ten,), rate=8.0, horizon=0.8, seed=9)
    backend = make_backend("cluster", workers=2, seed=9)
    try:
        code = MatDotCode(2, 4, x_complex(4, 0.1))
        sched = MasterScheduler(
            code, backend,
            ServeConfig(deadlines=(0.5, 1.0), batch_size=2, seed=9,
                        queue_policy="edf", queue_limit=4))
        report = run_load(sched, wl, horizon=0.8)
    finally:
        backend.close()
    assert report.served + report.shed + report.dropped == report.offered
    assert report.served > 0
    for res in sched.run_open([]) or []:       # empty workload is a no-op
        raise AssertionError("empty workload must serve nothing")
