"""Benchmark-regression gate logic (``benchmarks/compare.py``) and the
atomic ``BENCH_summary.json`` writer.

The gate guards every future PR's perf numbers, so its own semantics are
tier-1: regressions in gated metrics fail, improvements and noise-floor
motion pass, dropped rows/metrics fail loudly, and machine-dependent
timings are only gated on request.
"""
import json
import os

import pytest

from benchmarks.compare import _parse_metrics, compare_rows


def _row(name, derived, us=100.0):
    return {"name": name, "us_per_call": us, "derived": derived}


BASE = [
    _row("engine/total", "speedup=8.0x;trials=10"),
    _row("fig3a/code", "first_m=8;err_m8=0.040;err_m15=2.4e-16"),
    _row("table1/exact", "R=15;err_at_R=4.1e-18"),
    _row("elastic/savings", "saved=2.5x;elastic_ws=12.2;elastic_err=4e-04"),
]


def test_parse_metrics_handles_suffixes_and_labels():
    m = _parse_metrics("speedup=39.6x;pick=gsac[8]@0.1;hit_rate=50%;n=3")
    assert m == {"speedup": 39.6, "hit_rate": 50.0, "n": 3.0}


def test_identical_and_improved_runs_pass():
    assert compare_rows(BASE, BASE, tolerance=0.2, time_tolerance=None) == []
    better = [_row("engine/total", "speedup=12.0x;trials=10"),
              _row("fig3a/code", "first_m=8;err_m8=0.020;err_m15=1e-16"),
              _row("table1/exact", "R=15;err_at_R=1.0e-20"),
              _row("elastic/savings",
                   "saved=3.1x;elastic_ws=9.0;elastic_err=2e-04")]
    assert compare_rows(BASE, better, tolerance=0.2,
                        time_tolerance=None) == []


def test_wallclock_ratio_tolerates_load_jitter_but_not_collapse():
    # -37% on a wall-clock speedup is machine-load territory: tolerated
    cur = [dict(r) for r in BASE]
    cur[0] = _row("engine/total", "speedup=5.0x;trials=10")
    assert compare_rows(BASE, cur, tolerance=0.2, time_tolerance=None) == []
    # -62% is a collapsed optimization: fails the wider ratio tolerance
    cur[0] = _row("engine/total", "speedup=3.0x;trials=10")
    probs = compare_rows(BASE, cur, tolerance=0.2, time_tolerance=None)
    assert len(probs) == 1 and "speedup" in probs[0]


def test_error_regression_fails_but_noise_floor_passes():
    cur = [dict(r) for r in BASE]
    cur[1] = _row("fig3a/code", "first_m=8;err_m8=0.080;err_m15=2.4e-16")
    probs = compare_rows(BASE, cur, tolerance=0.2, time_tolerance=None)
    assert len(probs) == 1 and "err_m8" in probs[0]
    # exact-recovery residuals live at the float noise floor: relative
    # motion below 1e-12 is not a regression
    cur2 = [dict(r) for r in BASE]
    cur2[2] = _row("table1/exact", "R=15;err_at_R=8.8e-14")
    assert compare_rows(BASE, cur2, tolerance=0.2, time_tolerance=None) == []


def test_dropped_row_and_disappeared_metric_fail():
    probs = compare_rows(BASE, BASE[:-1], tolerance=0.2, time_tolerance=None)
    assert len(probs) == 1 and "missing" in probs[0]
    cur = [dict(r) for r in BASE]
    cur[3] = _row("elastic/savings", "elastic_ws=12.2;elastic_err=4e-04")
    probs = compare_rows(BASE, cur, tolerance=0.2, time_tolerance=None)
    assert len(probs) == 1 and "disappeared" in probs[0]
    assert "saved" in probs[0]


def test_metrics_subdict_unknown_keys_are_ignored():
    # observability counter snapshots ride rows as a `metrics` sub-dict:
    # unknown names (pool.*, transport.* ...) must never trip the gate,
    # and malformed payloads must not break the parse
    base = [dict(BASE[0], metrics={"pool.crashed": 1,
                                   "transport.bytes_sent": 9000,
                                   "label": "not-a-number"})]
    cur = [dict(BASE[0], metrics={"pool.crashed": 5,
                                  "transport.bytes_sent": 1,
                                  "extra.key": 7.5})]
    assert compare_rows(base, cur, tolerance=0.2, time_tolerance=None) == []
    assert compare_rows(base, [dict(BASE[0], metrics="garbage")],
                        tolerance=0.2, time_tolerance=None) == []


def test_metrics_subdict_known_keys_are_gated():
    # a gated name inside the sub-dict behaves exactly like one parsed
    # from the derived string — regression fails, improvement passes
    base = [dict(BASE[0], metrics={"hit_rate": 80.0})]
    good = [dict(BASE[0], metrics={"hit_rate": 90.0})]
    bad = [dict(BASE[0], metrics={"hit_rate": 20.0})]
    assert compare_rows(base, good, tolerance=0.2, time_tolerance=None) == []
    probs = compare_rows(base, bad, tolerance=0.2, time_tolerance=None)
    assert len(probs) == 1 and "hit_rate" in probs[0]


def test_timing_gate_is_opt_in():
    slow = [dict(r, us_per_call=r["us_per_call"] * 10) for r in BASE]
    assert compare_rows(BASE, slow, tolerance=0.2, time_tolerance=None) == []
    probs = compare_rows(BASE, slow, tolerance=0.2, time_tolerance=2.0)
    assert probs and all("us_per_call" in p for p in probs)


def test_committed_baseline_is_valid_and_self_consistent():
    """The baseline in the repo must parse and pass against itself —
    otherwise the CI gate is wedged from the start."""
    path = os.path.join(os.path.dirname(__file__), "..", "results", "bench",
                        "BENCH_baseline.json")
    with open(path) as f:
        baseline = json.load(f)
    rows = baseline["rows"]
    assert rows, "committed baseline has no rows"
    assert compare_rows(rows, rows, tolerance=0.2, time_tolerance=None) == []
    names = [r["name"] for r in rows]
    assert "fleet_elastic/savings" in names     # the new benchmark is gated
    saved = _parse_metrics(
        next(r for r in rows if r["name"] == "fleet_elastic/savings")
        ["derived"])["saved"]
    assert saved >= 1.5                          # the ISSUE acceptance bar


def test_write_bench_json_is_atomic(tmp_path, monkeypatch):
    """A crash mid-dump must never leave a truncated artifact: the writer
    goes through a temp file + rename."""
    from benchmarks import common
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    monkeypatch.setattr(common, "_ROWS", [{"name": "a", "us_per_call": 1.0,
                                           "derived": "x=1"}])
    path = common.write_bench_json("out.json")
    with open(path) as f:
        assert json.load(f)["rows"][0]["name"] == "a"
    # a payload json cannot serialize must not clobber the good artifact
    monkeypatch.setattr(common, "_ROWS", [{"bad": object()}])
    with pytest.raises(TypeError):
        common.write_bench_json("out.json")
    with open(path) as f:
        assert json.load(f)["rows"][0]["name"] == "a"   # previous intact
    assert [p for p in os.listdir(tmp_path)
            if p.endswith(".tmp")] == []                # no litter either
