"""Model-level consistency: decode == full forward, MoE vs oracle, caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import (decode_step, embed_tokens, forward_hidden,
                          init_params, prefill, compute_logits)

DENSE = ArchConfig("dense-s", "dense", 3, 64, 4, 2, 128, 97, qkv_bias=True,
                   dtype="float32")
SSM = ArchConfig("ssm-s", "ssm", 2, 64, 0, 0, 128, 97, ssm_state=4,
                 d_inner=128, pos_embed="none", dtype="float32")
HYB = ArchConfig("hyb-s", "hybrid", 3, 64, 4, 2, 128, 97, ssm_state=4,
                 d_inner=128, sliding_window=8, global_attn_layers=(1,),
                 dtype="float32")
AUD = ArchConfig("aud-s", "audio", 2, 64, 4, 4, 128, 50, n_codebooks=4,
                 pos_embed="sinusoidal", mlp_act="gelu", dtype="float32")


def _full_logits(params, tokens, cfg):
    x = embed_tokens(params, tokens, cfg)
    B, L = tokens.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    h, _ = forward_hidden(params, x, cfg, pos)
    if cfg.n_codebooks:
        return jnp.stack([compute_logits(params, h, cfg, c)
                          for c in range(cfg.n_codebooks)], axis=2)
    return compute_logits(params, h, cfg)


@pytest.mark.parametrize("cfg", [DENSE, SSM, HYB, AUD],
                         ids=lambda c: c.name)
def test_prefill_plus_decode_matches_forward(cfg):
    """logits from prefill(t<n) + decode(t_n) == full forward at position n."""
    key = jax.random.key(0)
    params = init_params(key, cfg, jnp.float32)
    B, L = 2, 12
    shape = (B, L, cfg.n_codebooks) if cfg.n_codebooks else (B, L)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    full = _full_logits(params, tokens, cfg)          # (B, L, [cb,] V)

    n = 8
    logits_pre, state = prefill(params, tokens[:, :n], cfg, max_seq=L)
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                               np.asarray(full[:, n - 1]),
                               rtol=2e-4, atol=2e-4)
    # now decode the next tokens one by one
    for t in range(n, L):
        tok = tokens[:, t:t + 1]
        logits, state = decode_step(params, tok, state, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer_decode():
    """Window-only arch: ring-buffer cache == unbounded cache decode."""
    cfg = ArchConfig("swa", "dense", 2, 64, 4, 2, 128, 97, sliding_window=6,
                     dtype="float32")
    key = jax.random.key(1)
    params = init_params(key, cfg, jnp.float32)
    B, L = 1, 16
    tokens = jax.random.randint(key, (B, L), 0, 97)
    full = _full_logits(params, tokens, cfg)
    _, state = prefill(params, tokens[:, :4], cfg, max_seq=cfg.sliding_window)
    assert state.kv_k.shape[3] == cfg.sliding_window     # window-sized cache
    for t in range(4, L):
        logits, state = decode_step(params, tokens[:, t:t + 1], state, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), rtol=2e-3,
                                   atol=2e-3)


def test_moe_block_matches_oracle_high_capacity():
    from repro.models.moe import init_moe_params, moe_block, moe_ref
    cfg = ArchConfig("m", "moe", 1, 32, 2, 2, 0, 97, n_experts=4,
                     experts_per_token=2, d_ff_expert=16, n_shared_experts=2,
                     capacity_factor=8.0)
    p = init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (40, 32))
    out, aux = moe_block(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(moe_ref(p, x, cfg)),
                               rtol=1e-5, atol=1e-5)
    assert aux.shape == () and float(aux) >= 1.0 - 1e-6  # E·Σf·P >= 1


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models.moe import init_moe_params, moe_block
    cfg = ArchConfig("m", "moe", 1, 32, 2, 2, 0, 97, n_experts=4,
                     experts_per_token=2, d_ff_expert=16,
                     capacity_factor=0.1)
    p = init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, 32))
    out, _ = moe_block(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(out)))          # drops, no NaNs


def test_vlm_loss_covers_text_only():
    cfg = ArchConfig("v", "vlm", 2, 64, 4, 2, 128, 97, vision_tokens=4,
                     dtype="float32")
    from repro.models import lm_loss
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    key = jax.random.key(2)
    batch = {"tokens": jax.random.randint(key, (2, 10), 0, 97),
             "vision_embeds": jax.random.normal(key, (2, 4, 64))}
    loss = lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    # vision embeds must influence the loss (they're attended to)
    batch2 = dict(batch, vision_embeds=batch["vision_embeds"] * 3.0)
    loss2 = lm_loss(params, batch2, cfg)
    assert abs(float(loss) - float(loss2)) > 1e-6


def test_cost_mode_same_loss():
    """cost_mode (unrolled/materialized) computes the SAME function."""
    from repro.models import lm_loss
    cfg = DENSE
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, 97)
    l1 = lm_loss(params, {"tokens": tokens}, cfg)
    l2 = lm_loss(params, {"tokens": tokens},
                 cfg.replace(cost_mode=True, use_scan=False))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
