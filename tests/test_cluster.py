"""Cluster runtime: pool lifecycle, chaos, record/replay bit-identity.

The load-bearing test is record/replay equivalence: a live cluster run
(real processes, measured arrival events) re-served through
``ReplayBackend`` must produce *identical* answers — same products (the
worker einsum is a width-1 slice of the simulated backend's contraction on
the same memory layout), same event order (arrival timestamps are strictly
increasing), same deadline semantics (``merged_event_stream`` tie rule).

Chaos tests pin the failure-mode contracts with bounded wall-clock: a crash
mid-batch loses exactly the dead worker's shard and heals by replacement; a
hung worker is abandoned at the grace bound and retired; the pool's
acquire/release/lease lifecycle keeps warm spares.
"""
import time

import numpy as np
import pytest

from repro.cluster import BatchRecord, ChaosSpec, TraceRecording, WorkerPool
from repro.cluster.backend import ClusterBackend, ReplayBackend
from repro.core import GroupSACCode, LayerSACCode, MatDotCode, x_complex
from repro.design.policy import RequestClass, SpeculationPolicy
from repro.serving import (DecodeWeightCache,
                           MasterScheduler, ServeConfig, SimulatedBackend,
                           make_backend)

K, N = 2, 4


def _serve(sched, reqs):
    for A, B in reqs:
        sched.submit(A, B)
    out = []
    for res in sched.run():
        out.append((res.ttfa, res.t_exact,
                    [(a.t, a.m, a.rel_err, a.exact, a.kind)
                     for a in res.answers]))
    return out


def _reqs(rng, n, rows=8, inner=4 * K):
    return [(rng.standard_normal((rows, inner)),
             rng.standard_normal((inner, rows))) for _ in range(n)]


# ----------------------------------------------------------------- chaos spec

def test_chaos_spec_parse():
    spec = ChaosSpec.parse("crash:1,sleep:0.01:0.05,slow:3:0.4,hang:2")
    assert spec.crash == 1 and spec.hang == 2
    assert spec.slow == 3 and spec.slow_delay == 0.4
    assert spec.sleep == (0.01, 0.05)
    assert ChaosSpec.parse(None) == ChaosSpec()
    assert ChaosSpec.parse("sleep:0.2").sleep == (0.0, 0.2)
    # deterministic designation: crash ids, then hang ids, then slow ids
    assert spec.plan_for(0).crash and not spec.plan_for(1).crash
    assert spec.plan_for(1).hang and spec.plan_for(2).hang
    assert spec.plan_for(3).slow_delay == 0.4
    assert spec.plan_for(6).slow_delay == 0.0     # past every doomed range
    with pytest.raises(ValueError, match="unknown chaos kind"):
        ChaosSpec.parse("explode:1")
    with pytest.raises(ValueError, match="malformed"):
        ChaosSpec.parse("crash:lots")
    with pytest.raises(ValueError, match="sleep"):
        ChaosSpec.parse("sleep:0.5:0.1")


def test_make_backend_rejects_unknown_name_listing_valid():
    with pytest.raises(ValueError, match="unknown backend .*valid: .*cluster.*sim"):
        make_backend("gpu")


# ----------------------------------------------------------------- pool

def test_pool_acquire_release_with_warm_spares():
    with WorkerPool(2, spares=1, seed=0) as pool:
        assert pool.size == 2 and pool.spares == 0
        spawned = pool.stats["spawned"]
        wids = pool.active
        pool.release(wids[1:])                 # one goes warm
        assert pool.size == 1 and pool.spares == 1
        got = pool.acquire(1)                  # warm spare reused: no spawn
        assert len(got) == 1
        assert pool.stats["spawned"] == spawned
        pool.release(pool.active)              # beyond the spare budget
        assert pool.size == 0 and pool.spares == 1
        # lease rightsizes in both directions and returns live workers
        fleet = pool.lease(3)
        assert len(fleet) == 3 and pool.size == 3
        assert pool.lease(2) == fleet[:2]
    assert pool.spares == 0                    # context exit shut it down


def test_pool_heartbeat_and_replacement_after_crash():
    t0 = time.monotonic()
    with WorkerPool(2, chaos="crash:1", seed=0) as pool:
        pool.wait_ready()
        beats = pool.heartbeat(timeout=5.0)
        assert set(beats) == set(pool.active)  # everyone idle answers
        # first task kills worker 0 (chaos); reap must replace it
        victim, survivor = pool.active
        pool.send(victim, ("task", 1, 0, ("x", (1,), "<f8"),
                           ("x", (1,), "<f8")))
        deadline = time.monotonic() + 10.0
        dead = []
        while not dead and time.monotonic() < deadline:
            dead = pool.reap(replace=True)
            time.sleep(0.02)
        assert [wid for wid, _ in dead] == [victim]
        assert dead[0][1] == {(1, 0)}          # the in-flight shard it took
        assert pool.size == 2                  # healed to the leased size
        assert victim not in pool.active
        # the replacement takes the corpse's *lease slot* — shard->worker
        # (and the profile's per-shard column identity) must not rotate
        assert pool.active[0] != victim and pool.active[1] == survivor
        assert pool.stats["replaced"] == 1 and pool.stats["crashed"] == 1
        assert pool.stats["shards_lost"] == 1
    assert time.monotonic() - t0 < 30.0


# ------------------------------------------------------- products equivalence

def test_cluster_products_bit_match_simulated():
    """Worker products == host einsum, bitwise, through the unified
    event-stream dispatch (the only execution surface since the two-call
    protocol was removed)."""
    rng = np.random.default_rng(0)
    code = MatDotCode(K, N, x_complex(N, 0.1))
    As, Bs = zip(*_reqs(rng, 3))
    with ClusterBackend(workers=N, seed=0) as be:
        d = be.dispatch_batch(code, As, Bs)
        d.drain(30.0)
        got = d.product_stack()
        times = d.latency_row()
        d.finalize()
    want = SimulatedBackend().compute_products(code, As, Bs)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(got, want)
    assert np.all(np.isfinite(times)) and len(times) == N
    assert np.all(np.diff(np.sort(times)) > 0)    # strictly increasing


def test_two_call_protocol_is_gone():
    """The deprecated ``batch_products``/``sample_latencies`` shims were
    deleted outright: ``dispatch_batch`` is the one execution surface, and
    nothing resurrects the old names on the base class or its children."""
    from repro.serving.backends import ExecutionBackend
    for cls in (ExecutionBackend, SimulatedBackend, ClusterBackend):
        assert not hasattr(cls, "batch_products")
        assert not hasattr(cls, "sample_latencies")


# ------------------------------------------------------ record/replay pinning

@pytest.mark.parametrize("make_code", [
    lambda: MatDotCode(K, 6, x_complex(6, 0.1)),
    lambda: LayerSACCode(2, 6, base="ortho", eps=6.25e-3),
    lambda: GroupSACCode(2, 6, x_complex(6, 0.1), [1, 1]),
])
def test_record_replay_bit_identity(make_code):
    """Cluster decode outputs == simulated decode on the recorded trace.

    ``stream=True`` exercises both answer kinds (per-event and per-tick) in
    one live run; equality is exact (``==`` on floats), not approximate.
    """
    code = make_code()
    rng = np.random.default_rng(7)
    reqs = _reqs(rng, 4)
    cfg = ServeConfig(deadlines=(0.05, 0.2, 0.6), stream=True, batch_size=2,
                      seed=0)
    with ClusterBackend(workers=code.N, chaos="sleep:0.005:0.02", seed=1,
                        record=True) as be:
        live = _serve(MasterScheduler(code, be, cfg), reqs)
        rec = be.recording
    assert len(rec) == 2                       # one record per dispatch
    replay = _serve(MasterScheduler(code, ReplayBackend(rec), cfg), reqs)
    assert live == replay

    # and the recording survives a JSON round-trip exactly
    rec2 = TraceRecording.from_dict(rec.to_dict())
    replay2 = _serve(MasterScheduler(code, ReplayBackend(rec2), cfg), reqs)
    assert live == replay2


def test_record_replay_bit_identity_with_lost_shards():
    """A lossy trace (crash mid-batch) still replays bit-identically: the
    recorded ``inf`` latency keeps the lost shard out of the replayed event
    stream, the profile feed, and the threshold times — exactly like the
    live loss."""
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(11)
    reqs = _reqs(rng, 4)
    cfg = ServeConfig(deadlines=(0.3, 0.8), stream=True, batch_size=2,
                      seed=0)
    with ClusterBackend(workers=N, chaos="crash:1,sleep:0.005:0.02",
                        seed=6, grace=3.0, record=True) as be:
        sched = MasterScheduler(code, be, cfg)
        live = _serve(sched, reqs)
        rec = be.recording
    assert sched.losses and sched.losses[0][2] == "crash"
    assert rec.batches[0].lost == {0: "crash"}
    assert np.isinf(rec.batches[0].latency_row()[0])
    replay = _serve(MasterScheduler(code, ReplayBackend(rec), cfg), reqs)
    assert live == replay


def test_all_shards_lost_sync_path_stays_bounded():
    """Every worker crashing must not wedge (or crash) the blocking drain
    path: the stack comes back zero-filled, latencies all ``inf``, within
    the sync timeout."""
    t0 = time.monotonic()
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(13)
    As, Bs = zip(*_reqs(rng, 2))
    with ClusterBackend(workers=N, chaos=f"crash:{N}", seed=0,
                        sync_timeout=10.0) as be:
        d = be.dispatch_batch(code, As, Bs)
        d.drain(be.sync_timeout)
        out = d.product_stack()
        times = d.latency_row()
        d.finalize()
    assert out.shape == (2, N, 8, 8) and not out.any()
    assert np.isinf(times).all()
    assert time.monotonic() - t0 < 60.0


def test_replay_backend_guards():
    rec = TraceRecording()
    rec.append(BatchRecord(n_shards=4, times={0: 0.1}))
    rb = ReplayBackend(rec)
    with pytest.raises(ValueError, match="shards"):
        rb.draw_latencies(np.random.default_rng(0), 6)
    rb = ReplayBackend(rec)
    row = rb.draw_latencies(np.random.default_rng(0), 4)
    assert row[0] == 0.1 and np.isinf(row[1:]).all()
    with pytest.raises(ValueError, match="exhausted"):
        rb.draw_latencies(np.random.default_rng(0), 4)


# -------------------------------------------------------------- chaos serving

def test_crash_mid_batch_loses_one_shard_and_heals():
    """Worker 0 dies on its first task: batch 0 decodes exactly from the
    N-1 survivors (R <= N-1), the pool replaces the corpse, batch 1 is
    whole again.  Bounded wall-clock end to end."""
    t0 = time.monotonic()
    code = MatDotCode(K, N, x_complex(N, 0.1))     # R = 3 of N = 4
    rng = np.random.default_rng(3)
    cfg = ServeConfig(deadlines=(1.0,), batch_size=2, seed=0)
    with ClusterBackend(workers=N, chaos="crash:1,sleep:0.005:0.02",
                        seed=2, grace=3.0) as be:
        sched = MasterScheduler(code, be, cfg)
        out = _serve(sched, _reqs(rng, 4))
        stats = be.pool.stats
    assert [(b, s, why) for b, s, why in sched.losses] == [(0, 0, "crash")]
    assert stats["replaced"] == 1 and stats["crashed"] == 1
    for ttfa, t_exact, answers in out[:2]:         # batch 0: m = 3, exact
        assert t_exact is not None
        assert answers[-1][1] == 3 and answers[-1][3]
        assert answers[-1][2] < 1e-20
    for ttfa, t_exact, answers in out[2:]:         # batch 1: all 4 arrive
        assert answers[-1][1] == 4 and answers[-1][3]
    assert time.monotonic() - t0 < 60.0


def test_hang_past_deadline_is_abandoned_and_retired():
    """A hung worker never reports; its shard resolves as a timeout loss at
    ``last deadline + grace`` and the worker is killed + replaced — the
    batch (and the test) stays bounded."""
    t0 = time.monotonic()
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(5)
    cfg = ServeConfig(deadlines=(0.4,), batch_size=2, seed=0)
    with ClusterBackend(workers=N, chaos="hang:1,sleep:0.005:0.02",
                        seed=4, grace=0.5) as be:
        sched = MasterScheduler(code, be, cfg)
        out = _serve(sched, _reqs(rng, 2))
        stats = be.pool.stats
    assert [(s, why) for _, s, why in sched.losses] == [(0, "timeout")]
    assert stats["retired"] == 1 and stats["replaced"] == 1
    assert stats["shards_lost"] == 1           # timeout losses are counted
    (ttfa, t_exact, answers), *_ = out
    assert t_exact is not None and answers[-1][1] == 3    # exact without it
    assert time.monotonic() - t0 < 60.0


# ----------------------------------------------------- speculative re-dispatch

def test_speculate_crash_requeues_shard_no_loss():
    """``speculate=True`` turns the crash loss into a re-queue: worker 0
    dies on its first task, the shard is re-sent to its lease slot's
    replacement, and *nothing* is lost — contrast with
    ``test_crash_mid_batch_loses_one_shard_and_heals``, the same chaos
    without speculation (opt-in preserved)."""
    t0 = time.monotonic()
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(3)
    cfg = ServeConfig(deadlines=(1.0,), batch_size=2, seed=0)
    with ClusterBackend(workers=N, chaos="crash:1,sleep:0.005:0.02",
                        seed=2, grace=3.0, speculate=True) as be:
        sched = MasterScheduler(code, be, cfg,
                                speculation=SpeculationPolicy())
        out = _serve(sched, _reqs(rng, 4))
        stats = be.pool.stats
    assert sched.losses == []
    assert "crash" in {why for _, _, why in sched.speculations}
    assert stats["shards_requeued"] >= 1
    assert stats["shards_lost"] == 0           # the re-queue compensated
    assert stats["replaced"] == 1 and stats["crashed"] == 1
    for ttfa, t_exact, answers in out:
        assert t_exact is not None and answers[-1][3]
    assert time.monotonic() - t0 < 60.0


def test_speculate_hedges_hung_shard_backup_wins():
    """Zero-slack MatDot (N = R = 3) with a hung worker: without a second
    copy the batch can never go exact.  The hedging policy re-dispatches
    the lagging shard to a leased backup, the backup's completion wins
    (flagged ``speculative``), and the hung loser is cancelled — counted
    apart from losses."""
    t0 = time.monotonic()
    code = MatDotCode(2, 3, x_complex(3, 0.1))
    rng = np.random.default_rng(5)
    cfg = ServeConfig(deadlines=(0.5,), batch_size=2, seed=0)
    with ClusterBackend(workers=3, chaos="hang:1,sleep:0.005:0.02",
                        seed=4, grace=2.0, speculate=True) as be:
        sched = MasterScheduler(code, be, cfg,
                                speculation=SpeculationPolicy())
        out = _serve(sched, _reqs(rng, 2))
        stats = be.pool.stats
    assert "hedge" in {why for _, _, why in sched.speculations}
    assert sched.losses == []                  # the backup rescued the batch
    assert stats["backups_leased"] >= 1
    assert stats["shards_cancelled"] >= 1      # the hung primary lost the race
    assert stats["shards_lost"] == 0
    (ttfa, t_exact, answers), *_ = out
    assert t_exact is not None and answers[-1][1] == 3 and answers[-1][3]
    assert time.monotonic() - t0 < 60.0


def test_speculate_slow_shard_rescued_before_delay():
    """A persistently slow (not dead) primary: the hedge races a backup
    against it and the batch reaches exactness well before the slow
    worker's delay would have allowed."""
    t0 = time.monotonic()
    delay = 2.0
    code = MatDotCode(2, 3, x_complex(3, 0.1))
    rng = np.random.default_rng(7)
    cfg = ServeConfig(deadlines=(0.5,), batch_size=2, seed=0)
    with ClusterBackend(workers=3, chaos=f"slow:1:{delay},sleep:0.005:0.02",
                        seed=6, grace=3.0, speculate=True) as be:
        sched = MasterScheduler(code, be, cfg,
                                speculation=SpeculationPolicy())
        out = _serve(sched, _reqs(rng, 2))
    assert "hedge" in {why for _, _, why in sched.speculations}
    assert sched.losses == []
    (ttfa, t_exact, answers), *_ = out
    assert t_exact is not None and t_exact < delay
    assert time.monotonic() - t0 < 60.0


def test_dispatch_first_wins_cancels_loser_and_reaps_duplicate():
    """Force-hedge a slow shard: the backup's completion wins and is
    flagged ``speculative``, the slow primary is cancelled, and its late
    result is swallowed by the dispatch accounting (``duplicates_reaped``)
    while a hung shard keeps the stream pumping — the consumer never sees
    the same shard twice."""
    t0 = time.monotonic()
    code = MatDotCode(2, 3, x_complex(3, 0.1))
    rng = np.random.default_rng(1)
    As, Bs = zip(*_reqs(rng, 2))
    with ClusterBackend(workers=3, chaos="hang:1,slow:1:1.0", seed=0,
                        speculate=True) as be:
        d = be.dispatch_batch(code, As, Bs)
        assert d.speculate(1)              # hedge the slow worker's shard
        d.set_abandon(2.5)                 # bound the hung shard
        done, kinds = {}, []
        while d.outstanding:
            ev = d.next_event(timeout=5.0)
            if ev is None:
                break
            kinds.append(ev.kind)
            if ev.kind == "done":
                assert ev.shard not in done    # delivered at most once
                done[ev.shard] = ev
        stats = dict(be.pool.stats)
        d.finalize()
    assert kinds.count("redispatch") == 1
    assert done[1].speculative             # the backup won shard 1
    assert not done[2].speculative         # untouched shard: primary won
    assert d.lost == {0: "timeout"}        # the hung shard resolved as loss
    assert d.record().redispatches == [[1, "hedge"]]
    assert stats["shards_cancelled"] == 1
    assert stats["duplicates_reaped"] == 1  # the loser's late result
    assert stats["shards_lost"] == 1        # hang only; cancel is separate
    assert time.monotonic() - t0 < 60.0


def test_record_replay_bit_identity_speculative_trace():
    """A trace with mid-batch re-dispatches replays bit-identically: the
    replay consumes only the final per-shard outcome (the race winner's
    time), so hedged batches reproduce the live answers exactly — and the
    ``redispatches`` metadata survives the JSON round-trip."""
    code = MatDotCode(2, 3, x_complex(3, 0.1))
    rng = np.random.default_rng(17)
    reqs = _reqs(rng, 4)
    cfg = ServeConfig(deadlines=(0.5,), stream=True, batch_size=2, seed=0)
    with ClusterBackend(workers=3, chaos="hang:1,sleep:0.005:0.02",
                        seed=9, grace=2.0, speculate=True, record=True) as be:
        sched = MasterScheduler(code, be, cfg,
                                speculation=SpeculationPolicy())
        live = _serve(sched, reqs)
        rec = be.recording
    assert sched.speculations                   # the hedge actually fired
    assert any(b.redispatches for b in rec.batches)
    replay = _serve(MasterScheduler(code, ReplayBackend(rec), cfg), reqs)
    assert live == replay

    rec2 = TraceRecording.from_dict(rec.to_dict())
    assert [b.redispatches for b in rec2.batches] == \
        [b.redispatches for b in rec.batches]
    replay2 = _serve(MasterScheduler(code, ReplayBackend(rec2), cfg), reqs)
    assert live == replay2


def test_replicate_pins_upfront_copies():
    """``replicate=2`` is the policy-free baseline: every shard gets a
    second copy at dispatch time, so a crashed primary's shard is still
    served by its surviving replica — at ~2x worker cost."""
    t0 = time.monotonic()
    code = MatDotCode(2, 3, x_complex(3, 0.1))
    rng = np.random.default_rng(19)
    cfg = ServeConfig(deadlines=(0.5,), batch_size=2, seed=0)
    with ClusterBackend(workers=3, chaos="crash:1,sleep:0.005:0.02",
                        seed=10, grace=2.0, replicate=2) as be:
        sched = MasterScheduler(code, be, cfg)
        out = _serve(sched, _reqs(rng, 2))
        stats = be.pool.stats
    assert {why for _, _, why in sched.speculations} == {"replicate"}
    assert len(sched.speculations) == 3         # one pinned copy per shard
    assert sched.losses == []
    assert stats["backups_leased"] >= 3
    (ttfa, t_exact, answers), *_ = out
    assert t_exact is not None and answers[-1][3]
    assert time.monotonic() - t0 < 60.0


# ------------------------------------------- compute seam: device vs numpy

DEVICE_FAMILIES = [
    ("matdot_complex", lambda: MatDotCode(2, 6, x_complex(6, 0.1)), 1e-5),
    ("gsac_complex",
     lambda: GroupSACCode(2, 6, x_complex(6, 0.1), [1, 1]), 1e-5),
    ("lsac_ortho_real",
     lambda: LayerSACCode(2, 6, base="ortho", eps=6.25e-3), 1e-5),
]


@pytest.mark.parametrize("family,make_code,tol", DEVICE_FAMILIES,
                         ids=[t[0] for t in DEVICE_FAMILIES])
def test_device_computer_matches_numpy_per_code_family(family, make_code,
                                                       tol):
    """The compute seam's accuracy contract, pinned per code family: every
    shard's device product (float32 kernel ops; complex operands via the
    4-real-GEMM expansion, so the device never sees a complex dtype) stays
    within relative tolerance of the numpy einsum."""
    from repro.cluster import ComputeSpec, make_computer
    from repro.serving.backends import ExecutionBackend
    code = make_code()
    rng = np.random.default_rng(23)
    As, Bs = zip(*_reqs(rng, 2))
    E_A, E_B = ExecutionBackend._encode_batch(code, As, Bs)
    base = make_computer(ComputeSpec.parse("numpy"))
    for shard in range(code.N):
        want = base.shard_products(E_A, E_B, shard)
        dev = make_computer(ComputeSpec.parse("device").for_worker(shard))
        got = dev.shard_products(E_A, E_B, shard)
        assert got.shape == want.shape
        rel = np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-30)
        assert rel < tol, (family, shard, rel)


# --------------------------------------------- transport seam: serve parity

def test_socket_transport_crash_loss_and_replay_bit_identity():
    """numpy x socket: the TCP transport serves the same crash semantics as
    the pipes (worker 0's EOF surfaces as a clean shard loss, the pool
    heals by replacement) and its measured trace replays bit-identically."""
    t0 = time.monotonic()
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(3)
    reqs = _reqs(rng, 4)
    cfg = ServeConfig(deadlines=(1.0,), stream=True, batch_size=2, seed=0)
    with ClusterBackend(workers=N, chaos="crash:1,sleep:0.005:0.02",
                        seed=2, grace=3.0, record=True,
                        transport="socket") as be:
        sched = MasterScheduler(code, be, cfg)
        live = _serve(sched, reqs)
        rec = be.recording
        stats = be.pool.stats
    assert [(b, s, why) for b, s, why in sched.losses] == [(0, 0, "crash")]
    assert stats["replaced"] == 1 and stats["crashed"] == 1
    replay = _serve(MasterScheduler(code, ReplayBackend(rec), cfg), reqs)
    assert live == replay
    assert time.monotonic() - t0 < 60.0


def test_device_compute_serve_and_replay_bit_identity():
    """device x socket — both seams stretched at once: Pallas kernel-op
    products on each worker's pinned device, shipped over TCP.  The live
    answers replay bit-identically only through a device-mode
    ``ReplayBackend``; the numpy replay differs in the float32 low bits,
    proving the recorded trace pins the compute seam too."""
    t0 = time.monotonic()
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(31)
    reqs = _reqs(rng, 2)
    cfg = ServeConfig(deadlines=(1.0,), stream=True, batch_size=2, seed=0)
    with ClusterBackend(workers=N, chaos="sleep:0.005:0.02", seed=8,
                        record=True, compute="device",
                        transport="socket") as be:
        live = _serve(MasterScheduler(code, be, cfg), reqs)
        rec = be.recording
    dev = _serve(MasterScheduler(code, ReplayBackend(rec, compute="device"),
                                 cfg), reqs)
    assert live == dev
    host = _serve(MasterScheduler(code, ReplayBackend(rec), cfg), reqs)
    assert live != host
    assert time.monotonic() - t0 < 120.0


def test_transport_releases_operands_on_crash_and_teardown():
    """Published operand blocks never outlive their dispatch: the worker
    endpoint closes its shm attachments on every exit path (even a crash
    mid-task), every finalized dispatch releases its publication, and the
    transport holds zero live publications through close()."""
    code = MatDotCode(K, N, x_complex(N, 0.1))
    rng = np.random.default_rng(29)
    cfg = ServeConfig(deadlines=(1.0,), batch_size=2, seed=0)
    be = ClusterBackend(workers=N, chaos="crash:1,sleep:0.005:0.02",
                        seed=2, grace=3.0)
    try:
        sched = MasterScheduler(code, be, cfg)
        _serve(sched, _reqs(rng, 4))
        assert sched.losses                        # the crash really fired
        assert be.pool.transport.live_operands == 0
    finally:
        be.close()
    assert be.pool.transport.live_operands == 0


# ---------------------------------------------- async/sim surface equivalence

def test_async_scheduler_falls_back_on_modeled_backends():
    """MasterScheduler over a modeled backend (its ``dispatch_batch``
    is the synthetic-event adapter over ``compute_products`` +
    ``draw_latencies``) serves exactly like MasterScheduler — same rng
    stream, same answers: one event loop, no modeled/live fork left."""
    code = MatDotCode(K, 8, x_complex(8, 0.1))
    rng = np.random.default_rng(9)
    reqs = _reqs(rng, 3)
    cfg = ServeConfig(deadlines=(1.2, 2.0), batch_size=2, seed=7)
    a = _serve(MasterScheduler(code, SimulatedBackend(), cfg), reqs)
    b = _serve(MasterScheduler(code, SimulatedBackend(), cfg), reqs)
    assert a == b


# ------------------------------------------------------- per-class cache LRU

def _key(i):
    return (("code", i), frozenset({i}), 1, "one")


def test_cache_class_budgets_isolate_eviction():
    big = RequestClass(rows=64, inner=128, dtype="f8")
    small = RequestClass(rows=8, inner=64, dtype="f8")
    cache = DecodeWeightCache(maxsize=4, class_budgets={big: 2})
    v = (np.zeros(1), None)
    bview = cache.for_class(big)
    sview = cache.for_class(small)
    # the budgeted class evicts only within its own sub-LRU
    for i in range(5):
        bview.put(_key(i), v)
    assert bview.get(_key(3)) is not None and bview.get(_key(4)) is not None
    assert bview.get(_key(0)) is None              # evicted at budget 2
    # the unbudgeted class rides the shared LRU, untouched by big's churn
    sview.put(_key(100), v)
    assert sview.get(_key(100)) is not None
    assert len(cache) == 3                         # 2 budgeted + 1 shared
    st = cache.stats()["classes"]
    assert st[big]["budget"] == 2 and st[big]["size"] == 2
    assert st[small]["budget"] is None             # shared fallback
    assert st[small]["hits"] == 1
    assert cache.hits == st[big]["hits"] + st[small]["hits"]


def test_cache_default_class_budget_and_plain_path():
    cache = DecodeWeightCache(maxsize=4, class_budget=1)
    cls = RequestClass(rows=8, inner=64, dtype="f8")
    view = cache.for_class(cls)
    v = (np.zeros(1), None)
    view.put(_key(0), v)
    view.put(_key(1), v)
    assert view.get(_key(0)) is None and view.get(_key(1)) is not None
    # class-free path is the historical shared LRU, stats() shape intact
    plain = DecodeWeightCache(maxsize=2)
    assert plain.for_class(cls) is plain
    plain.put(_key(0), v)
    assert plain.get(_key(0)) is not None
    assert "classes" not in plain.stats()
    with pytest.raises(ValueError, match="class_budget"):
        DecodeWeightCache(class_budget=0)


def test_scheduler_routes_decoders_through_class_views():
    code = MatDotCode(K, 8, x_complex(8, 0.1))
    cache = DecodeWeightCache(maxsize=64, class_budget=8)
    cfg = ServeConfig(deadlines=(1.2, 2.0), batch_size=2, seed=1)
    sched = MasterScheduler(code, SimulatedBackend(), cfg, cache)
    rng = np.random.default_rng(2)
    _serve(sched, _reqs(rng, 2) + _reqs(rng, 2, rows=16, inner=8 * K))
    st = cache.stats()
    assert "classes" in st and len(st["classes"]) == 2
    assert all(c["hits"] + c["misses"] > 0 for c in st["classes"].values())


# ------------------------------------------------------ drift-aware scale-out

def test_policy_scale_out_requests_larger_fleet_on_worse_tail():
    from repro.design import AdaptivePolicy, CodeSpace
    space = CodeSpace(2, 4, families=("matdot",), N_options=(4, 8))
    # deadline tight enough that under the worsened tail *no* fleet meets
    # the target — the normal pick misses, which is exactly the regime the
    # scale-out hook exists for (more workers = closest to the target)
    policy = AdaptivePolicy(space, deadline=2.0, target_error=1e-2,
                            window=4, trials=64, seed=0, drift="ks",
                            cost_aware=True, scale_out=True)
    rng = np.random.default_rng(0)
    # fast regime: everything completes well before the deadline
    code = None
    for _ in range(6):
        policy.observe(0.2 + rng.exponential(0.1, size=4))
        code = policy.maybe_retune() or code
    assert policy.history and policy.history[0].trigger == "window"
    first = policy.current_point
    assert first.cost == 4                     # cheapest fleet meets target
    # tail worsens hard: N=4 can no longer meet the target by the deadline
    switched = None
    for _ in range(80):
        policy.observe(1.5 + rng.exponential(1.25, size=4))
        switched = policy.maybe_retune() or switched
        if policy.history[-1].trigger.startswith("drift"):
            break
    last = policy.history[-1]
    assert last.trigger == "drift-scale-out"
    assert last.point.cost == 8                # the fleet request grew
    assert switched is not None and switched.N == 8


def test_policy_scale_out_no_ratchet_when_workers_buy_nothing():
    """Every fleet size fails identically (deadline shorter than any
    completion): repeated drift hits must NOT ratchet the fleet upward —
    extra workers that buy zero accuracy are never requested."""
    from repro.design import AdaptivePolicy, CodeSpace
    space = CodeSpace(2, 4, families=("matdot",), N_options=(4, 8))
    policy = AdaptivePolicy(space, deadline=0.05, target_error=1e-2,
                            window=4, trials=16, seed=0, drift="ks",
                            cost_aware=True, scale_out=True)
    rng = np.random.default_rng(2)
    for _ in range(6):
        policy.observe(0.2 + rng.exponential(0.1, size=4))
        policy.maybe_retune()
    cold_cost = policy.current_point.cost
    for _ in range(80):
        policy.observe(1.5 + rng.exponential(1.25, size=4))
        policy.maybe_retune()
        if len(policy.history) > 1:
            break
    assert all(ev.trigger != "drift-scale-out" for ev in policy.history)
    assert policy.current_point.cost == cold_cost


def test_policy_scale_out_stays_put_when_target_still_met():
    from repro.design import AdaptivePolicy, CodeSpace
    space = CodeSpace(2, 4, families=("matdot",), N_options=(4, 8))
    policy = AdaptivePolicy(space, deadline=2.5, target_error=0.5,
                            window=4, trials=32, seed=0, drift="ks",
                            cost_aware=True, scale_out=True)
    rng = np.random.default_rng(1)
    for _ in range(6):
        policy.observe(0.2 + rng.exponential(0.1, size=4))
        policy.maybe_retune()
    # a mild slowdown that still meets the loose target: no scale-out
    for _ in range(80):
        policy.observe(0.4 + rng.exponential(0.2, size=4))
        policy.maybe_retune()
        if len(policy.history) > 1:
            break
    assert all(ev.trigger != "drift-scale-out" for ev in policy.history)
    assert policy.current_point.cost == 4
